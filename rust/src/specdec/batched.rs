//! Batched speculative decoding: B independent sequences advance in
//! lockstep rounds sharing the model forwards (the paper's batch=64/128
//! rows in Table 1, and the serving batcher's execution mode).
//!
//! Per round: one batched [`BatchDraftSource::propose`] produces γ
//! proposals per sequence (for the model-backed source that is γ batched
//! draft extends, exactly the pre-refactor execution; draft-free sources
//! run their closed-form/learned heads per sequence), then one batched
//! target extend validates every sequence's γ+1 prefix conditionals.
//! Sequences accept/reject independently, so each sequence's state is
//! rolled back by its own rejected-suffix length — with the KV cache on,
//! that is a per-sequence cache truncation instead of a context rebuild.
//! With the cache off the sessions fall back to left-aligned zero-padded
//! batched re-forwards (causality makes tail padding inert), the exact
//! execution shape of the stateless decoder. Finished sequences drop out
//! of the advancing set; queued tasks take their slots immediately
//! (continuous batching, paper §5.5).
//!
//! Wall-clock shape: on the native backend the batched `extend` calls
//! below (draft proposals and the target verify) fan their per-sequence
//! incremental forwards across the shared worker pool
//! (`NativeBatchSession`, kernel-layer PR), so a lockstep round costs the
//! *max* of its sequences instead of their sum — outputs are bitwise
//! independent of the thread count, so everything this module pins about
//! cache on/off equivalence is untouched. The per-round `draft_time` /
//! `target_time` attribution divides the round wall clock evenly across
//! the active set, which under the parallel verify is the honest
//! per-sequence share of the (now overlapped) round.

use std::time::Instant;

use anyhow::Result;

use super::controller::GammaController;
use super::draft::{make_batch_source, BatchDraftSource, RoundFeedback};
use super::engine::{Emission, SpecConfig, Variant};
use super::stats::{DecodeOutput, DecodeStats, RoundStats};
use crate::models::{begin_batch_session, Backend};
use crate::util::rng::Rng;

struct SeqState {
    out: Vec<f32>,
    horizon: usize,
    emitted: usize,
    rounds: Vec<RoundStats>,
    stats: DecodeStats,
    /// Per-sequence adaptive controller (present iff `cfg.adaptive`).
    /// Sequences adapt independently: a hostile stream collapses its own
    /// γ without dragging its batchmates down.
    ctrl: Option<GammaController>,
}

impl SeqState {
    fn remaining(&self) -> usize {
        self.horizon - self.emitted
    }
    fn done(&self) -> bool {
        self.emitted >= self.horizon
    }
}

/// Decode a batch of (history, n_hist, horizon) tasks in one lockstep
/// group; returns one [`DecodeOutput`] per task, in order. The draft
/// side is built from [`SpecConfig::draft`] (see [`super::draft`]).
pub fn sd_generate_batch(
    target: &dyn Backend,
    draft: &dyn Backend,
    tasks: &[(&[f32], usize, usize)],
    cfg: &SpecConfig,
) -> Result<Vec<DecodeOutput>> {
    sd_generate_stream(target, draft, tasks, usize::MAX, cfg)
}

/// Continuous batching: at most `max_active` sequences advance per round;
/// as sequences finish, queued tasks immediately take their slots. This is
/// the vLLM-style scheduling (paper §5.5) that removes lockstep straggler
/// waste — a batch does not wait for its slowest member before admitting
/// new work.
pub fn sd_generate_stream(
    target: &dyn Backend,
    draft: &dyn Backend,
    tasks: &[(&[f32], usize, usize)],
    max_active: usize,
    cfg: &SpecConfig,
) -> Result<Vec<DecodeOutput>> {
    anyhow::ensure!(target.patch() == draft.patch(), "patch mismatch");
    let mut source = make_batch_source(&cfg.draft, draft)?;
    sd_generate_stream_from(target, source.as_mut(), tasks, max_active, cfg)
}

/// [`sd_generate_stream`] over a caller-owned [`BatchDraftSource`]
/// (learned per-sequence state persists across calls when the caller
/// keeps the source alive).
pub fn sd_generate_stream_from(
    target: &dyn Backend,
    source: &mut dyn BatchDraftSource,
    tasks: &[(&[f32], usize, usize)],
    max_active: usize,
    cfg: &SpecConfig,
) -> Result<Vec<DecodeOutput>> {
    let p = target.patch();
    anyhow::ensure!(p == source.patch(), "patch mismatch");
    anyhow::ensure!(cfg.gamma >= 1);
    anyhow::ensure!(
        cfg.k == 1,
        "tree speculation (k > 1) is single-stream only; the serving \
         batcher runs k > 1 requests as per-job tree decodes — the batch \
         axis is spent on branches, not sequences"
    );
    if cfg.variant == Variant::Lossless {
        anyhow::ensure!((cfg.policy.bias - 1.0).abs() < 1e-12, "lossless requires bias=1");
        anyhow::ensure!(cfg.emission == Emission::Sampled, "lossless requires Emission::Sampled");
    }
    if let Some(acfg) = &cfg.adaptive {
        acfg.validate()?;
        anyhow::ensure!(
            !acfg.sigma_adapt,
            "sigma adaptation is single-stream only (proposals in a lockstep \
             batch share one acceptance policy); use gamma-only adaptation here"
        );
        anyhow::ensure!(
            acfg.k_max == 1,
            "adaptive tree speculation (k_max > 1) is single-stream only; \
             lockstep batches share one verify extend per round"
        );
    }
    let max_ctx = target.max_ctx().min(source.max_ctx());
    // The same config-vs-backend check the single-stream engine runs up
    // front (the max_ctx footgun fix): never start a decode whose opening
    // γ can only blow up at the first window slide.
    anyhow::ensure!(
        cfg.gamma + 1 < max_ctx,
        "gamma {} cannot fit the joint context window: a round appends \
         gamma + 1 patches and must keep at least one context patch \
         (target max_ctx {}, draft max_ctx {}) — lower gamma or raise \
         the binding side's context",
        cfg.gamma,
        target.max_ctx(),
        source.max_ctx()
    );

    // Validate every task before the clamp below slices into it: a short
    // history must stay the clean "history too short" error it always
    // was, never a slice panic on the serving engine thread.
    for (h, n, _) in tasks {
        anyhow::ensure!(*n >= 1, "session needs at least one history patch");
        anyhow::ensure!(h.len() >= *n * p, "history too short");
    }
    // Clamp every opening history to the joint window so the target
    // sessions and the draft source stay aligned patch-for-patch even
    // when their max_ctx differ.
    let clamped: Vec<(&[f32], usize)> = tasks
        .iter()
        .map(|(h, n, _)| {
            let keep = (*n).min(max_ctx);
            (&h[(*n - keep) * p..*n * p], keep)
        })
        .collect();

    // Long-lived per-sequence target sessions + the draft source. Jobs
    // keep these across all their rounds; rejection rolls back, nothing
    // is rebuilt.
    let mut t_bs = begin_batch_session(target, cfg.cache, &clamped)?;
    source.begin(&clamped, cfg.cache)?;
    let upd0: Vec<usize> = (0..tasks.len()).map(|i| source.updates(i)).collect();

    // Per-sequence RNG streams, kept beside (not inside) the sequence
    // states so the draft source can sample through them while the loop
    // still mutates `seqs`.
    let mut rngs: Vec<Rng> = (0..tasks.len())
        .map(|i| Rng::new(cfg.seed.wrapping_add(i as u64 * 0x9E37_79B9)))
        .collect();
    let mut seqs: Vec<SeqState> = tasks
        .iter()
        .map(|(_, _, horizon)| SeqState {
            out: Vec::with_capacity(horizon * p),
            horizon: *horizon,
            emitted: 0,
            rounds: Vec::new(),
            stats: DecodeStats::default(),
            ctrl: cfg
                .adaptive
                .map(|acfg| GammaController::new(acfg, cfg.gamma, cfg.policy.sigma)),
        })
        .collect();

    anyhow::ensure!(max_active >= 1);
    loop {
        // Admission: the first `max_active` unfinished sequences (slots
        // freed by finished sequences are refilled immediately).
        let active: Vec<usize> =
            (0..seqs.len()).filter(|&i| !seqs[i].done()).take(max_active).collect();
        if active.is_empty() {
            break;
        }
        let a = active.len();
        // Per-sequence desired γ for this round: the controller's current
        // recommendation (context-clamped) under adaptation, else the
        // static γ — capped by the sequence's own remaining horizon.
        let desired: Vec<usize> = active
            .iter()
            .map(|&i| {
                let want = match &seqs[i].ctrl {
                    Some(c) => c.gamma_for(max_ctx),
                    None => cfg.gamma,
                };
                want.min(seqs[i].remaining().saturating_sub(1))
            })
            .collect();
        // Round γ: the max desired across the batch — every sequence's
        // proposals fit inside the shared lockstep round; sequences
        // wanting less scan (and keep) only their own prefix.
        let gamma = desired.iter().copied().max().unwrap().max(1);

        // Slide windows that would overflow (target and draft in lockstep).
        for &i in &active {
            let n_now = t_bs.len(i);
            if n_now + gamma + 1 > max_ctx {
                anyhow::ensure!(gamma + 1 < max_ctx, "gamma {gamma} cannot fit in max_ctx {max_ctx}");
                let keep = max_ctx - (gamma + 1);
                t_bs.evict_to(i, keep)?;
                source.evict_to(i, keep)?;
            }
        }

        // --- Draft: one batched propose (γ proposals per active
        // sequence, sampled through the per-sequence RNG streams).
        let t0 = Instant::now();
        let blocks = source.propose(&active, gamma, cfg.policy.sigma, &mut rngs)?;
        let draft_time = t0.elapsed();
        anyhow::ensure!(blocks.len() == a, "draft source returned {} blocks for {a}", blocks.len());

        // --- Target: one batched extend validates every sequence's γ+1
        // prefix conditionals.
        let mut flat = vec![0.0f32; a * gamma * p];
        for (ai, block) in blocks.iter().enumerate() {
            anyhow::ensure!(
                block.proposals.len() == gamma && block.mu_qs.len() == gamma,
                "draft source returned {}/{} proposals/means for gamma {gamma}",
                block.proposals.len(),
                block.mu_qs.len()
            );
            for (x, m) in block.proposals.iter().zip(&block.mu_qs) {
                super::engine::ensure_finite(x, "draft proposal")?;
                super::engine::ensure_finite(m, "draft mean")?;
            }
            for (k, x) in block.proposals.iter().enumerate() {
                flat[ai * gamma * p + k * p..ai * gamma * p + (k + 1) * p].copy_from_slice(x);
            }
        }
        let t1 = Instant::now();
        let val_rows = t_bs.extend(&active, &flat, gamma)?; // [a, gamma+1, p]
        let target_time = t1.elapsed();
        super::engine::ensure_finite(&val_rows, "target validation means")?;

        // --- Per-sequence acceptance + rollback + emission.
        for (ai, &i) in active.iter().enumerate() {
            // Each sequence's post-work (scan, rollback, appends, residual
            // draws) is timed individually so one slow sequence does not
            // inflate its batchmates' stats.
            let tpost = Instant::now();
            let base = ai * (gamma + 1) * p;
            let mu_p_at = |k: usize| &val_rows[base + k * p..base + (k + 1) * p];
            let proposals = &blocks[ai].proposals;
            let mu_qs = &blocks[ai].mu_qs;

            // Per-sequence gamma: a sequence near its horizon (or whose
            // controller wants a shorter block) only consumes the
            // proposals it can still use (the round's extra draft work is
            // lockstep overhead, but acceptance statistics stay honest —
            // without this, tail truncation deflates measured E[L]).
            let g_i = desired[ai];
            let mut alphas = Vec::with_capacity(g_i);
            let mut accepted = 0usize;
            let mut rejected_at = None;
            for k in 0..g_i {
                let alpha = cfg.policy.alpha(&proposals[k], mu_p_at(k), &mu_qs[k]);
                alphas.push(alpha);
                if alpha >= 1.0 || rngs[i].uniform() < alpha {
                    accepted += 1;
                } else {
                    rejected_at = Some(k);
                    break;
                }
            }

            // Roll this sequence's target session back to its accepted
            // prefix (the source rewinds itself in finish_round below).
            let mut emit: Vec<f32> = Vec::with_capacity((accepted + 1) * p);
            match cfg.emission {
                Emission::Sampled => {
                    t_bs.rollback(i, gamma - accepted)?;
                    for x in &proposals[..accepted] {
                        emit.extend_from_slice(x);
                    }
                }
                Emission::Mean => {
                    t_bs.rollback(i, gamma)?;
                    for m in &mu_qs[..accepted] {
                        emit.extend_from_slice(m);
                    }
                    if accepted > 0 {
                        t_bs.append(i, &emit, accepted)?;
                    }
                }
            }

            let mut residual_draws = 0usize;
            let final_mu: Vec<f32> = match rejected_at {
                None => mu_p_at(g_i).to_vec(),
                Some(k) => mu_p_at(k).to_vec(),
            };
            let final_patch = match (rejected_at, cfg.variant) {
                (Some(k), Variant::Lossless) => {
                    // Shared residual-thinning helper (engine.rs): RNG
                    // consumption is part of the bit-exactness contract.
                    let (z, draws) = super::engine::residual_thin(
                        &final_mu,
                        &mu_qs[k],
                        cfg.policy.sigma,
                        cfg.max_residual_draws,
                        &mut rngs[i],
                    );
                    residual_draws = draws;
                    z
                }
                _ => match cfg.emission {
                    Emission::Sampled => {
                        let mut z = vec![0.0f32; p];
                        rngs[i].fill_normal_around(&final_mu, cfg.policy.sigma as f32, &mut z);
                        z
                    }
                    Emission::Mean => final_mu,
                },
            };
            t_bs.append(i, &final_patch, 1)?;
            let tpost_elapsed = tpost.elapsed();

            // --- Verification feedback to the draft side (draft-cost
            // work: rollback, commit, online update flush).
            let tfin = Instant::now();
            source.finish_round(
                i,
                &RoundFeedback {
                    gamma,
                    accepted,
                    alphas: &alphas,
                    target_means: &val_rows[base..base + (gamma + 1) * p],
                    committed: &emit,
                    final_patch: &final_patch,
                    sampled: cfg.emission == Emission::Sampled,
                },
            )?;
            let fin_elapsed = tfin.elapsed();
            emit.extend_from_slice(&final_patch);

            // accepted <= g_i <= remaining - 1, so take never truncates now;
            // keep the min as a defensive invariant.
            let take = (accepted + 1).min(seqs[i].remaining());
            debug_assert_eq!(take, accepted + 1);
            seqs[i].out.extend_from_slice(&emit[..take * p]);
            seqs[i].emitted += take;

            let r = RoundStats {
                gamma: g_i,
                accepted,
                emitted: take,
                alphas,
                residual_draws,
                branches: 1,
                draft_time: draft_time / a as u32 + fin_elapsed,
                target_time: target_time / a as u32 + tpost_elapsed,
            };
            if let Some(c) = &mut seqs[i].ctrl {
                c.observe_round(&r);
            }
            super::observer::notify_round(i, &r);
            seqs[i].stats.absorb(&r);
            seqs[i].rounds.push(r);
        }
    }

    for (i, s) in seqs.iter_mut().enumerate() {
        s.stats.draft_updates = source.updates(i).saturating_sub(upd0[i]);
    }
    Ok(seqs
        .into_iter()
        .map(|s| DecodeOutput { patches: s.out, rounds: s.rounds, stats: s.stats })
        .collect())
}

/// [`sd_generate_stream_from`] with **per-task seeds** and a
/// **per-sequence-exact** execution discipline: every sequence's decode is
/// bit-identical to running [`super::sd_generate_from`] alone on that task
/// with the same seed — for *any* batch composition, admission order, or
/// `max_active` (the serving scheduler's replica-count-invariance
/// contract).
///
/// What the default lockstep loop couples across batchmates, this one
/// decouples:
/// * **RNG** — sequence `i` draws from `Rng::new(seeds[i])`, not from a
///   batch-index-derived stream.
/// * **Round γ** — instead of one round-wide `max(desired)` block length
///   (which makes a tail sequence consume extra proposal draws), each
///   round *buckets* the active set by per-sequence desired γ and runs one
///   batched propose/extend per bucket. A sequence therefore executes
///   exactly the session ops and RNG draws of its solo decode; batchmates
///   only determine who shares a batched `extend` call — and batched
///   extends are bitwise equal to singles (`tests/kernel_equivalence.rs`).
/// * **Eviction** — window slides use the sequence's own γ+1 need, not the
///   round max.
///
/// The γ = 0 horizon tail runs the solo engine's plain target AR step
/// (the default lockstep loop instead rounds the block length up to 1).
/// Bit-exactness across grouping holds for [`CacheMode::On`] sessions
/// (per-sequence serial kernels); `Off` falls back to padded batched
/// re-forwards, which are observationally — not bit — identical.
pub fn sd_generate_stream_seeded(
    target: &dyn Backend,
    source: &mut dyn BatchDraftSource,
    tasks: &[(&[f32], usize, usize)],
    seeds: &[u64],
    max_active: usize,
    cfg: &SpecConfig,
) -> Result<Vec<DecodeOutput>> {
    let p = target.patch();
    anyhow::ensure!(p == source.patch(), "patch mismatch");
    anyhow::ensure!(cfg.gamma >= 1);
    anyhow::ensure!(
        cfg.k == 1,
        "tree speculation (k > 1) is single-stream only; the serving \
         batcher runs k > 1 requests as per-job tree decodes — the batch \
         axis is spent on branches, not sequences"
    );
    anyhow::ensure!(
        seeds.len() == tasks.len(),
        "got {} seeds for {} tasks",
        seeds.len(),
        tasks.len()
    );
    if cfg.variant == Variant::Lossless {
        anyhow::ensure!((cfg.policy.bias - 1.0).abs() < 1e-12, "lossless requires bias=1");
        anyhow::ensure!(cfg.emission == Emission::Sampled, "lossless requires Emission::Sampled");
    }
    if let Some(acfg) = &cfg.adaptive {
        acfg.validate()?;
        anyhow::ensure!(
            !acfg.sigma_adapt,
            "sigma adaptation is single-stream only (proposals in a lockstep \
             batch share one acceptance policy); use gamma-only adaptation here"
        );
        anyhow::ensure!(
            acfg.k_max == 1,
            "adaptive tree speculation (k_max > 1) is single-stream only; \
             lockstep batches share one verify extend per round"
        );
    }
    let max_ctx = target.max_ctx().min(source.max_ctx());
    anyhow::ensure!(
        cfg.gamma + 1 < max_ctx,
        "gamma {} cannot fit the joint context window: a round appends \
         gamma + 1 patches and must keep at least one context patch \
         (target max_ctx {}, draft max_ctx {}) — lower gamma or raise \
         the binding side's context",
        cfg.gamma,
        target.max_ctx(),
        source.max_ctx()
    );
    for (h, n, _) in tasks {
        anyhow::ensure!(*n >= 1, "session needs at least one history patch");
        anyhow::ensure!(h.len() >= *n * p, "history too short");
    }
    let clamped: Vec<(&[f32], usize)> = tasks
        .iter()
        .map(|(h, n, _)| {
            let keep = (*n).min(max_ctx);
            (&h[(*n - keep) * p..*n * p], keep)
        })
        .collect();

    let mut t_bs = begin_batch_session(target, cfg.cache, &clamped)?;
    source.begin(&clamped, cfg.cache)?;
    let upd0: Vec<usize> = (0..tasks.len()).map(|i| source.updates(i)).collect();

    // The whole point: per-sequence streams seeded per *request*, so a
    // sequence's draws are a pure function of (its seed, its own decode).
    let mut rngs: Vec<Rng> = seeds.iter().map(|&s| Rng::new(s)).collect();
    let mut seqs: Vec<SeqState> = tasks
        .iter()
        .map(|(_, _, horizon)| SeqState {
            out: Vec::with_capacity(horizon * p),
            horizon: *horizon,
            emitted: 0,
            rounds: Vec::new(),
            stats: DecodeStats::default(),
            ctrl: cfg
                .adaptive
                .map(|acfg| GammaController::new(acfg, cfg.gamma, cfg.policy.sigma)),
        })
        .collect();

    anyhow::ensure!(max_active >= 1);
    loop {
        let active: Vec<usize> =
            (0..seqs.len()).filter(|&i| !seqs[i].done()).take(max_active).collect();
        if active.is_empty() {
            break;
        }
        // Per-sequence desired γ, exactly the solo engine's rule: the
        // controller's (context-clamped) recommendation or the static γ,
        // capped by the sequence's own remaining horizon — and *kept*
        // per-sequence: sequences are bucketed by desired γ instead of
        // rounded up to a shared max.
        let mut buckets: std::collections::BTreeMap<usize, Vec<usize>> = Default::default();
        for &i in &active {
            let want = match &seqs[i].ctrl {
                Some(c) => c.gamma_for(max_ctx),
                None => cfg.gamma,
            };
            let g = want.min(seqs[i].remaining().saturating_sub(1));
            buckets.entry(g).or_default().push(i);
        }
        for (gamma, idx) in buckets {
            // Window slides use each sequence's own need (solo rule).
            let need = gamma + 1;
            for &i in &idx {
                if t_bs.len(i) + need > max_ctx {
                    anyhow::ensure!(need < max_ctx, "gamma {gamma} cannot fit in max_ctx {max_ctx}");
                    let keep = max_ctx - need;
                    t_bs.evict_to(i, keep)?;
                    source.evict_to(i, keep)?;
                }
            }

            if gamma == 0 {
                // Horizon tail: the solo engine's plain target AR step.
                for &i in &idx {
                    let t0 = Instant::now();
                    let mu_p = t_bs.tip_means(&[i])?;
                    super::engine::ensure_finite(&mu_p, "target tip mean")?;
                    let patch = match cfg.emission {
                        Emission::Sampled => {
                            let mut buf = vec![0.0f32; p];
                            rngs[i].fill_normal_around(&mu_p, cfg.policy.sigma as f32, &mut buf);
                            buf
                        }
                        Emission::Mean => mu_p,
                    };
                    t_bs.append(i, &patch, 1)?;
                    let tt = t0.elapsed();
                    let t1 = Instant::now();
                    source.append(i, &patch, 1)?;
                    let dt = t1.elapsed();
                    seqs[i].out.extend_from_slice(&patch);
                    seqs[i].emitted += 1;
                    let r = RoundStats {
                        gamma: 0,
                        accepted: 0,
                        emitted: 1,
                        alphas: vec![],
                        residual_draws: 0,
                        branches: 1,
                        draft_time: dt,
                        target_time: tt,
                    };
                    if let Some(c) = &mut seqs[i].ctrl {
                        c.observe_round(&r);
                    }
                    super::observer::notify_round(i, &r);
                    seqs[i].stats.absorb(&r);
                    seqs[i].rounds.push(r);
                }
                continue;
            }

            let a = idx.len();
            let t0 = Instant::now();
            let blocks = source.propose(&idx, gamma, cfg.policy.sigma, &mut rngs)?;
            let draft_time = t0.elapsed();
            anyhow::ensure!(
                blocks.len() == a,
                "draft source returned {} blocks for {a}",
                blocks.len()
            );
            let mut flat = vec![0.0f32; a * gamma * p];
            for (ai, block) in blocks.iter().enumerate() {
                anyhow::ensure!(
                    block.proposals.len() == gamma && block.mu_qs.len() == gamma,
                    "draft source returned {}/{} proposals/means for gamma {gamma}",
                    block.proposals.len(),
                    block.mu_qs.len()
                );
                for (x, m) in block.proposals.iter().zip(&block.mu_qs) {
                    super::engine::ensure_finite(x, "draft proposal")?;
                    super::engine::ensure_finite(m, "draft mean")?;
                }
                for (k, x) in block.proposals.iter().enumerate() {
                    flat[ai * gamma * p + k * p..ai * gamma * p + (k + 1) * p].copy_from_slice(x);
                }
            }
            let t1 = Instant::now();
            let val_rows = t_bs.extend(&idx, &flat, gamma)?; // [a, gamma+1, p]
            let target_time = t1.elapsed();
            super::engine::ensure_finite(&val_rows, "target validation means")?;

            for (ai, &i) in idx.iter().enumerate() {
                let tpost = Instant::now();
                let base = ai * (gamma + 1) * p;
                let mu_p_at = |k: usize| &val_rows[base + k * p..base + (k + 1) * p];
                let proposals = &blocks[ai].proposals;
                let mu_qs = &blocks[ai].mu_qs;

                // Acceptance scan over the full bucket γ — which *is* the
                // sequence's own desired γ (no batchmate rounding).
                let mut alphas = Vec::with_capacity(gamma);
                let mut accepted = 0usize;
                let mut rejected_at = None;
                for k in 0..gamma {
                    let alpha = cfg.policy.alpha(&proposals[k], mu_p_at(k), &mu_qs[k]);
                    alphas.push(alpha);
                    if alpha >= 1.0 || rngs[i].uniform() < alpha {
                        accepted += 1;
                    } else {
                        rejected_at = Some(k);
                        break;
                    }
                }

                let mut emit: Vec<f32> = Vec::with_capacity((accepted + 1) * p);
                match cfg.emission {
                    Emission::Sampled => {
                        t_bs.rollback(i, gamma - accepted)?;
                        for x in &proposals[..accepted] {
                            emit.extend_from_slice(x);
                        }
                    }
                    Emission::Mean => {
                        t_bs.rollback(i, gamma)?;
                        for m in &mu_qs[..accepted] {
                            emit.extend_from_slice(m);
                        }
                        if accepted > 0 {
                            t_bs.append(i, &emit, accepted)?;
                        }
                    }
                }

                let mut residual_draws = 0usize;
                let final_mu: Vec<f32> = match rejected_at {
                    None => mu_p_at(gamma).to_vec(),
                    Some(k) => mu_p_at(k).to_vec(),
                };
                let final_patch = match (rejected_at, cfg.variant) {
                    (Some(k), Variant::Lossless) => {
                        // Shared residual-thinning helper (engine.rs) —
                        // the same code the solo path runs, which is what
                        // keeps this path solo-exact by construction.
                        let (z, draws) = super::engine::residual_thin(
                            &final_mu,
                            &mu_qs[k],
                            cfg.policy.sigma,
                            cfg.max_residual_draws,
                            &mut rngs[i],
                        );
                        residual_draws = draws;
                        z
                    }
                    _ => match cfg.emission {
                        Emission::Sampled => {
                            let mut z = vec![0.0f32; p];
                            rngs[i].fill_normal_around(&final_mu, cfg.policy.sigma as f32, &mut z);
                            z
                        }
                        Emission::Mean => final_mu,
                    },
                };
                t_bs.append(i, &final_patch, 1)?;
                let tpost_elapsed = tpost.elapsed();

                let tfin = Instant::now();
                source.finish_round(
                    i,
                    &RoundFeedback {
                        gamma,
                        accepted,
                        alphas: &alphas,
                        target_means: &val_rows[base..base + (gamma + 1) * p],
                        committed: &emit,
                        final_patch: &final_patch,
                        sampled: cfg.emission == Emission::Sampled,
                    },
                )?;
                let fin_elapsed = tfin.elapsed();
                emit.extend_from_slice(&final_patch);

                // gamma <= remaining - 1 by construction, so a round never
                // overshoots its sequence's horizon.
                let take = accepted + 1;
                debug_assert!(take <= seqs[i].remaining());
                seqs[i].out.extend_from_slice(&emit[..take * p]);
                seqs[i].emitted += take;

                let r = RoundStats {
                    gamma,
                    accepted,
                    emitted: take,
                    alphas,
                    residual_draws,
                    branches: 1,
                    draft_time: draft_time / a as u32 + fin_elapsed,
                    target_time: target_time / a as u32 + tpost_elapsed,
                };
                if let Some(c) = &mut seqs[i].ctrl {
                    c.observe_round(&r);
                }
                super::observer::notify_round(i, &r);
                seqs[i].stats.absorb(&r);
                seqs[i].rounds.push(r);
            }
        }
    }

    for (i, s) in seqs.iter_mut().enumerate() {
        s.stats.draft_updates = source.updates(i).saturating_sub(upd0[i]);
    }
    Ok(seqs
        .into_iter()
        .map(|s| DecodeOutput { patches: s.out, rounds: s.rounds, stats: s.stats })
        .collect())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::accept::AcceptancePolicy;
    use crate::models::{AnalyticBackend, CacheMode, NativeBackend};
    use crate::nn::model::tiny_model;
    use crate::specdec::draft::DraftConfig;

    fn cfg(gamma: usize, sigma: f64, seed: u64) -> SpecConfig {
        SpecConfig {
            gamma,
            k: 1,
            policy: AcceptancePolicy::new(sigma, 1.0),
            variant: Variant::Practical,
            seed,
            max_residual_draws: 1000,
            emission: Emission::Sampled,
            cache: CacheMode::On,
            draft: DraftConfig::default(),
            adaptive: None,
        }
    }

    #[test]
    fn batch_paths_reject_tree_k() {
        let t = AnalyticBackend::new("t", 2, 0.8, 0.1);
        let d = AnalyticBackend::new("d", 2, 0.75, 0.1);
        let h = vec![0.5f32, -0.5];
        let tasks: Vec<(&[f32], usize, usize)> = vec![(&h, 1, 4)];
        let mut c = cfg(2, 0.5, 1);
        c.k = 2;
        let err = sd_generate_batch(&t, &d, &tasks, &c).unwrap_err();
        assert!(format!("{err:#}").contains("single-stream"), "{err:#}");
        let mut src = make_batch_source(&c.draft, &d).unwrap();
        assert!(sd_generate_stream_seeded(&t, src.as_mut(), &tasks, &[1], usize::MAX, &c).is_err());
        // Adaptive k_max > 1 is rejected the same way.
        let mut c = cfg(2, 0.5, 1);
        c.adaptive = Some(crate::specdec::AdaptiveConfig {
            k_max: 4,
            ..crate::specdec::AdaptiveConfig::default()
        });
        assert!(sd_generate_batch(&t, &d, &tasks, &c).is_err());
    }

    #[test]
    fn batch_emits_exact_horizons() {
        let t = AnalyticBackend::new("t", 2, 0.8, 0.1);
        let d = AnalyticBackend::new("d", 2, 0.75, 0.1);
        let h1 = vec![0.5f32, -0.5];
        let h2 = vec![1.0f32, 0.0, 0.3, 0.3]; // 2 history patches
        let tasks: Vec<(&[f32], usize, usize)> =
            vec![(&h1, 1, 5), (&h2, 2, 9), (&h1, 1, 1)];
        let outs = sd_generate_batch(&t, &d, &tasks, &cfg(3, 0.5, 1)).unwrap();
        assert_eq!(outs[0].patches.len(), 5 * 2);
        assert_eq!(outs[1].patches.len(), 9 * 2);
        assert_eq!(outs[2].patches.len(), 1 * 2);
    }

    #[test]
    fn batch_of_one_matches_single_path_statistically() {
        // Same seed derivation differs, so compare aggregate acceptance
        // rather than exact values: identical models accept everything in
        // both paths.
        let t = AnalyticBackend::new("t", 2, 0.8, 0.1);
        let d = AnalyticBackend::new("d", 2, 0.8, 0.1);
        let h = vec![0.5f32, -0.5];
        let tasks: Vec<(&[f32], usize, usize)> = vec![(&h, 1, 12)];
        let outs = sd_generate_batch(&t, &d, &tasks, &cfg(3, 0.5, 2)).unwrap();
        assert_eq!(outs[0].stats.accepted, outs[0].stats.proposals);
        assert!((outs[0].stats.alpha_hat() - 1.0).abs() < 1e-9);
    }

    #[test]
    fn sequences_independent() {
        // A hostile sequence in the batch must not change another
        // sequence's acceptance behaviour (only its own).
        let t = AnalyticBackend::new("t", 1, 0.8, 0.0);
        let d = AnalyticBackend::new("d", 1, 0.8, 0.0);
        let good = vec![0.5f32];
        let tasks1: Vec<(&[f32], usize, usize)> = vec![(&good, 1, 10)];
        let solo = sd_generate_batch(&t, &d, &tasks1, &cfg(3, 0.4, 7)).unwrap();
        let weird = vec![99.0f32];
        let tasks2: Vec<(&[f32], usize, usize)> = vec![(&good, 1, 10), (&weird, 1, 10)];
        let pair = sd_generate_batch(&t, &d, &tasks2, &cfg(3, 0.4, 7)).unwrap();
        // Seq 0 has the same seed and same models in both runs.
        assert_eq!(solo[0].patches, pair[0].patches);
    }

    #[test]
    fn heterogeneous_lengths_are_padded_correctly() {
        // Mixed n_hist in one batch: results must equal the single-sequence
        // engine's acceptance pattern for identical models (all-accept).
        let t = AnalyticBackend::new("t", 1, 0.9, 0.05);
        let d = AnalyticBackend::new("d", 1, 0.9, 0.05);
        let h1 = vec![0.1f32];
        let h2 = vec![0.1f32, 0.2, 0.3, 0.4, 0.5];
        let tasks: Vec<(&[f32], usize, usize)> = vec![(&h1, 1, 6), (&h2, 5, 6)];
        let outs = sd_generate_batch(&t, &d, &tasks, &cfg(2, 0.5, 3)).unwrap();
        for o in &outs {
            assert_eq!(o.stats.accepted, o.stats.proposals, "identical heads must accept");
            assert_eq!(o.patches.len(), 6);
            assert!(o.patches.iter().all(|v| v.is_finite()));
        }
    }

    #[test]
    fn short_history_is_a_clean_error_not_a_panic() {
        let t = AnalyticBackend::new("t", 2, 0.8, 0.1);
        let d = AnalyticBackend::new("d", 2, 0.75, 0.1);
        let short = vec![0.5f32]; // 1 value, claims 2 patches of size 2
        let tasks: Vec<(&[f32], usize, usize)> = vec![(&short, 2, 4)];
        let err = sd_generate_batch(&t, &d, &tasks, &cfg(2, 0.5, 1)).unwrap_err();
        assert!(format!("{err:#}").contains("history too short"), "{err:#}");
        let zero: Vec<(&[f32], usize, usize)> = vec![(&short, 0, 4)];
        assert!(sd_generate_batch(&t, &d, &zero, &cfg(2, 0.5, 1)).is_err());
    }

    #[test]
    fn draft_free_batch_sources_emit_exact_horizons() {
        use crate::specdec::draft::DraftKind;
        let t = AnalyticBackend::new("t", 2, 0.8, 0.1);
        let d = AnalyticBackend::new("d", 2, 0.75, 0.1); // patch size only
        let h1 = vec![0.5f32, -0.5, 0.2, 0.4];
        let h2 = vec![1.0f32, 0.0];
        let tasks: Vec<(&[f32], usize, usize)> = vec![(&h1, 2, 7), (&h2, 1, 11)];
        for kind in [DraftKind::Extrap, DraftKind::Adaptive] {
            let mut c = cfg(3, 0.5, 5);
            c.draft.kind = kind;
            let outs = sd_generate_batch(&t, &d, &tasks, &c).unwrap();
            assert_eq!(outs[0].patches.len(), 7 * 2, "{kind:?}");
            assert_eq!(outs[1].patches.len(), 11 * 2, "{kind:?}");
            for o in &outs {
                assert!(o.patches.iter().all(|v| v.is_finite()));
            }
            if kind == DraftKind::Adaptive {
                assert!(
                    outs.iter().any(|o| o.stats.draft_updates > 0),
                    "adaptive batch sources never updated"
                );
            }
        }
    }

    #[test]
    fn adaptive_batch_emits_exact_horizons() {
        use crate::specdec::AdaptiveConfig;
        let t = AnalyticBackend::new("t", 2, 0.8, 0.1);
        let d = AnalyticBackend::new("d", 2, 0.8, 0.1);
        let h1 = vec![0.5f32, -0.5];
        let h2 = vec![1.0f32, 0.0, 0.3, 0.3];
        let tasks: Vec<(&[f32], usize, usize)> = vec![(&h1, 1, 30), (&h2, 2, 9), (&h1, 1, 1)];
        let mut c = cfg(2, 0.5, 5);
        c.adaptive = Some(AdaptiveConfig {
            warmup: 1,
            dwell: 1,
            halflife: 6.0,
            c_override: 0.05,
            ..AdaptiveConfig::default()
        });
        let outs = sd_generate_batch(&t, &d, &tasks, &c).unwrap();
        assert_eq!(outs[0].patches.len(), 30 * 2);
        assert_eq!(outs[1].patches.len(), 9 * 2);
        assert_eq!(outs[2].patches.len(), 1 * 2);
        // The long identical-model sequence must have adapted upward.
        let max_g = outs[0].rounds.iter().map(|r| r.gamma).max().unwrap();
        assert!(max_g > 2, "controller never adapted in batch (max gamma {max_g})");
    }

    #[test]
    fn adaptive_sequences_adapt_independently() {
        use crate::specdec::AdaptiveConfig;
        // The two heads agree where |x| is small and disagree violently
        // where |x| is large (mean gap = |x|), so a sequence starting at
        // 30 rejects nearly everything while a sequence near 0 accepts.
        // Per-sequence controllers must diverge: the hostile stream
        // collapses its own gamma without dragging its batchmate down.
        let t = AnalyticBackend::new("t", 1, 0.5, 0.0);
        let d = AnalyticBackend::new("d", 1, -0.5, 0.0);
        let good = vec![0.0f32];
        let hostile = vec![30.0f32];
        let tasks: Vec<(&[f32], usize, usize)> = vec![(&good, 1, 60), (&hostile, 1, 60)];
        let mut c = cfg(3, 0.5, 7);
        c.adaptive = Some(AdaptiveConfig {
            warmup: 1,
            dwell: 1,
            halflife: 6.0,
            c_override: 0.05,
            ..AdaptiveConfig::default()
        });
        let outs = sd_generate_batch(&t, &d, &tasks, &c).unwrap();
        for o in &outs {
            assert_eq!(o.patches.len(), 60);
        }
        // The hostile sequence must have dropped below its opening gamma
        // at some round; the good one must never have been dragged to 1
        // for long — compare the per-round gamma paths directly.
        let g_good: Vec<usize> = outs[0].rounds.iter().map(|r| r.gamma).collect();
        let g_host: Vec<usize> = outs[1].rounds.iter().map(|r| r.gamma).collect();
        assert!(g_host.iter().any(|&g| g == 1), "hostile stream never collapsed: {g_host:?}");
        assert!(
            g_good.iter().zip(&g_host).any(|(a, b)| a > b),
            "controllers never diverged: good {g_good:?} vs hostile {g_host:?}"
        );
    }

    #[test]
    fn adaptive_batch_rejects_sigma_adaptation() {
        use crate::specdec::AdaptiveConfig;
        let t = AnalyticBackend::new("t", 1, 0.8, 0.1);
        let d = AnalyticBackend::new("d", 1, 0.8, 0.1);
        let h = vec![0.1f32];
        let tasks: Vec<(&[f32], usize, usize)> = vec![(&h, 1, 4)];
        let mut c = cfg(2, 0.5, 3);
        c.adaptive = Some(AdaptiveConfig { sigma_adapt: true, ..AdaptiveConfig::default() });
        assert!(sd_generate_batch(&t, &d, &tasks, &c).is_err());
    }

    fn bits(v: &[f32]) -> Vec<u32> {
        v.iter().map(|x| x.to_bits()).collect()
    }

    /// The serving scheduler's core contract: a sequence decoded through
    /// `sd_generate_stream_seeded` is bit-identical to its solo
    /// `sd_generate_from` decode with the same seed, for every draft kind,
    /// variant, emission, batch composition, and admission cap — window
    /// slides and horizon tails included.
    #[test]
    fn seeded_batch_is_bitwise_identical_to_solo_decodes() {
        use crate::specdec::draft::{make_source, DraftKind};
        use crate::specdec::sd_generate_from;
        let t = NativeBackend::new(tiny_model(51));
        let d = NativeBackend::new(tiny_model(52));
        let h1: Vec<f32> = (0..2 * 4).map(|i| (i as f32 * 0.2).sin()).collect();
        let h2: Vec<f32> = (0..5 * 4).map(|i| (i as f32 * 0.13).cos()).collect();
        let h3: Vec<f32> = (0..3 * 4).map(|i| (i as f32 * 0.31).sin()).collect();
        // Horizon 11 on an 8-patch context forces slides; horizon 1 forces
        // the γ = 0 tail bucket.
        let tasks: Vec<(&[f32], usize, usize)> = vec![(&h1, 2, 11), (&h2, 5, 7), (&h3, 3, 1)];
        let seeds = [101u64, 202, 303];
        for kind in [DraftKind::Model, DraftKind::Extrap, DraftKind::Adaptive] {
            for (variant, emission) in [
                (Variant::Practical, Emission::Mean),
                (Variant::Practical, Emission::Sampled),
                (Variant::Lossless, Emission::Sampled),
            ] {
                let mut c = cfg(3, 0.5, 0);
                c.draft.kind = kind;
                c.variant = variant;
                c.emission = emission;
                let label = format!("{kind:?} {variant:?} {emission:?}");
                // Solo references: one fresh source per task (matching the
                // batch adapter's fresh per-sequence sources).
                let solo: Vec<DecodeOutput> = tasks
                    .iter()
                    .zip(&seeds)
                    .map(|(&(h, n, hz), &s)| {
                        let mut sc = c;
                        sc.seed = s;
                        let mut src = make_source(&sc.draft, &d).unwrap();
                        sd_generate_from(&t, src.as_mut(), h, n, hz, &sc).unwrap()
                    })
                    .collect();
                // All three in one batch.
                let mut src = make_batch_source(&c.draft, &d).unwrap();
                let outs =
                    sd_generate_stream_seeded(&t, src.as_mut(), &tasks, &seeds, usize::MAX, &c)
                        .unwrap();
                for (o, s) in outs.iter().zip(&solo) {
                    assert_eq!(bits(&o.patches), bits(&s.patches), "{label}");
                    assert_eq!(o.stats.accepted, s.stats.accepted, "{label}");
                    assert_eq!(o.stats.rounds, s.stats.rounds, "{label}");
                }
                // Continuous batching (max_active 2) must not change a
                // sequence's decode either.
                let mut src = make_batch_source(&c.draft, &d).unwrap();
                let capped =
                    sd_generate_stream_seeded(&t, src.as_mut(), &tasks, &seeds, 2, &c).unwrap();
                for (o, s) in capped.iter().zip(&solo) {
                    assert_eq!(bits(&o.patches), bits(&s.patches), "{label} max_active=2");
                }
                // A different composition/order: [task2, task0].
                let regroup: Vec<(&[f32], usize, usize)> = vec![tasks[2], tasks[0]];
                let rseeds = [seeds[2], seeds[0]];
                let mut src = make_batch_source(&c.draft, &d).unwrap();
                let outs2 =
                    sd_generate_stream_seeded(&t, src.as_mut(), &regroup, &rseeds, usize::MAX, &c)
                        .unwrap();
                assert_eq!(bits(&outs2[0].patches), bits(&solo[2].patches), "{label} regrouped");
                assert_eq!(bits(&outs2[1].patches), bits(&solo[0].patches), "{label} regrouped");
            }
        }
    }

    /// Per-sequence adaptive controllers make desired γ diverge across
    /// batchmates mid-decode; the bucketed rounds must still reproduce
    /// each solo adaptive decode bit-for-bit.
    #[test]
    fn seeded_batch_matches_solo_under_adaptive_gamma() {
        use crate::specdec::draft::make_source;
        use crate::specdec::{sd_generate_from, AdaptiveConfig};
        let t = NativeBackend::new(tiny_model(61));
        let d = NativeBackend::new(tiny_model(62));
        let h1: Vec<f32> = (0..2 * 4).map(|i| (i as f32 * 0.21).sin()).collect();
        let h2: Vec<f32> = (0..4 * 4).map(|i| (i as f32 * 0.17).cos()).collect();
        let tasks: Vec<(&[f32], usize, usize)> = vec![(&h1, 2, 14), (&h2, 4, 6)];
        let seeds = [7u64, 9];
        let mut c = cfg(2, 0.5, 0);
        c.adaptive = Some(AdaptiveConfig {
            warmup: 1,
            dwell: 1,
            halflife: 6.0,
            c_override: 0.05,
            ..AdaptiveConfig::default()
        });
        let solo: Vec<DecodeOutput> = tasks
            .iter()
            .zip(&seeds)
            .map(|(&(h, n, hz), &s)| {
                let mut sc = c;
                sc.seed = s;
                let mut src = make_source(&sc.draft, &d).unwrap();
                sd_generate_from(&t, src.as_mut(), h, n, hz, &sc).unwrap()
            })
            .collect();
        let mut src = make_batch_source(&c.draft, &d).unwrap();
        let outs =
            sd_generate_stream_seeded(&t, src.as_mut(), &tasks, &seeds, usize::MAX, &c).unwrap();
        for (o, s) in outs.iter().zip(&solo) {
            assert_eq!(bits(&o.patches), bits(&s.patches));
            let g_batch: Vec<usize> = o.rounds.iter().map(|r| r.gamma).collect();
            let g_solo: Vec<usize> = s.rounds.iter().map(|r| r.gamma).collect();
            assert_eq!(g_batch, g_solo, "per-round gamma schedules must match");
        }
    }

    #[test]
    fn seeded_batch_rejects_mismatched_seed_count() {
        let t = AnalyticBackend::new("t", 2, 0.8, 0.1);
        let d = AnalyticBackend::new("d", 2, 0.75, 0.1);
        let h = vec![0.5f32, -0.5];
        let tasks: Vec<(&[f32], usize, usize)> = vec![(&h, 1, 4)];
        let c = cfg(2, 0.5, 1);
        let mut src = make_batch_source(&c.draft, &d).unwrap();
        assert!(
            sd_generate_stream_seeded(&t, src.as_mut(), &tasks, &[1, 2], usize::MAX, &c).is_err()
        );
    }

    #[test]
    fn batched_cache_toggle_is_observationally_identical() {
        // Per-sequence KV rollback (cache on) vs padded batched re-forwards
        // (cache off) must yield the same decodes, including with mixed
        // history lengths and horizons that force window slides.
        let t = NativeBackend::new(tiny_model(41));
        let d = NativeBackend::new(tiny_model(42));
        let h1: Vec<f32> = (0..2 * 4).map(|i| (i as f32 * 0.2).sin()).collect();
        let h2: Vec<f32> = (0..4 * 4).map(|i| (i as f32 * 0.3).cos()).collect();
        let tasks: Vec<(&[f32], usize, usize)> = vec![(&h1, 2, 11), (&h2, 4, 7)];
        let mut on = cfg(3, 0.5, 9);
        on.cache = CacheMode::On;
        let mut off = on;
        off.cache = CacheMode::Off;
        let a = sd_generate_batch(&t, &d, &tasks, &on).unwrap();
        let b = sd_generate_batch(&t, &d, &tasks, &off).unwrap();
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.stats.accepted, y.stats.accepted);
            assert_eq!(x.stats.rounds, y.stats.rounds);
            assert_eq!(x.patches.len(), y.patches.len());
            for (u, v) in x.patches.iter().zip(&y.patches) {
                assert!((u - v).abs() < 1e-5, "cached {u} vs uncached {v}");
            }
        }
    }
}
