//! Batched speculative decoding: B independent sequences advance in
//! lockstep rounds sharing the model forwards (the paper's batch=64/128
//! rows in Table 1, and the serving batcher's execution mode).
//!
//! Per round: γ *batched* draft forwards propose one patch per sequence
//! each, then one batched target forward validates every sequence's γ+1
//! prefix conditionals. Sequences accept/reject independently, so context
//! lengths diverge; buffers are left-aligned and zero-padded to the round's
//! max length — causality makes tail padding inert, and each sequence reads
//! its own positions. Finished sequences drop out of the batch.

use std::time::Instant;

use anyhow::Result;

use super::engine::{Emission, SpecConfig, Variant};
use super::stats::{DecodeOutput, DecodeStats, RoundStats};
use crate::models::Backend;
use crate::util::rng::Rng;

struct SeqState {
    ctx: Vec<f32>,
    out: Vec<f32>,
    horizon: usize,
    emitted: usize,
    rng: Rng,
    rounds: Vec<RoundStats>,
    stats: DecodeStats,
}

impl SeqState {
    fn remaining(&self) -> usize {
        self.horizon - self.emitted
    }
    fn done(&self) -> bool {
        self.emitted >= self.horizon
    }
}

/// Decode a batch of (history, n_hist, horizon) tasks in one lockstep
/// group; returns one [`DecodeOutput`] per task, in order.
pub fn sd_generate_batch(
    target: &dyn Backend,
    draft: &dyn Backend,
    tasks: &[(&[f32], usize, usize)],
    cfg: &SpecConfig,
) -> Result<Vec<DecodeOutput>> {
    sd_generate_stream(target, draft, tasks, usize::MAX, cfg)
}

/// Continuous batching: at most `max_active` sequences advance per round;
/// as sequences finish, queued tasks immediately take their slots. This is
/// the vLLM-style scheduling (paper §5.5) that removes lockstep straggler
/// waste — a batch does not wait for its slowest member before admitting
/// new work.
pub fn sd_generate_stream(
    target: &dyn Backend,
    draft: &dyn Backend,
    tasks: &[(&[f32], usize, usize)],
    max_active: usize,
    cfg: &SpecConfig,
) -> Result<Vec<DecodeOutput>> {
    let p = target.patch();
    anyhow::ensure!(p == draft.patch(), "patch mismatch");
    anyhow::ensure!(cfg.gamma >= 1);
    if cfg.variant == Variant::Lossless {
        anyhow::ensure!((cfg.policy.bias - 1.0).abs() < 1e-12, "lossless requires bias=1");
        anyhow::ensure!(cfg.emission == Emission::Sampled, "lossless requires Emission::Sampled");
    }
    let max_ctx = target.max_ctx().min(draft.max_ctx());

    let mut seqs: Vec<SeqState> = tasks
        .iter()
        .enumerate()
        .map(|(i, (hist, n_hist, horizon))| SeqState {
            ctx: hist[..n_hist * p].to_vec(),
            out: Vec::with_capacity(horizon * p),
            horizon: *horizon,
            emitted: 0,
            rng: Rng::new(cfg.seed.wrapping_add(i as u64 * 0x9E37_79B9)),
            rounds: Vec::new(),
            stats: DecodeStats::default(),
        })
        .collect();

    anyhow::ensure!(max_active >= 1);
    loop {
        // Admission: the first `max_active` unfinished sequences (slots
        // freed by finished sequences are refilled immediately).
        let active: Vec<usize> =
            (0..seqs.len()).filter(|&i| !seqs[i].done()).take(max_active).collect();
        if active.is_empty() {
            break;
        }
        // Round γ: shared across the batch (sequences near their horizon
        // cap their own emissions after acceptance).
        let gamma = cfg
            .gamma
            .min(active.iter().map(|&i| seqs[i].remaining()).max().unwrap().saturating_sub(1))
            .max(1)
            .min(cfg.gamma);

        // Slide contexts that would overflow.
        for &i in &active {
            let n_now = seqs[i].ctx.len() / p;
            if n_now + gamma + 1 > max_ctx {
                let keep = max_ctx - (gamma + 1);
                let drop = n_now - keep;
                seqs[i].ctx.drain(..drop * p);
            }
        }
        let n0: Vec<usize> = active.iter().map(|&i| seqs[i].ctx.len() / p).collect();

        // --- Draft: gamma batched forwards.
        let mut proposals: Vec<Vec<Vec<f32>>> = vec![Vec::new(); active.len()]; // [seq][i][p]
        let mut mu_qs: Vec<Vec<Vec<f32>>> = vec![Vec::new(); active.len()];
        let t0 = Instant::now();
        for step in 0..gamma {
            let n_max = active
                .iter()
                .map(|&i| seqs[i].ctx.len() / p)
                .max()
                .unwrap();
            let mut buf = vec![0.0f32; active.len() * n_max * p];
            for (ai, &i) in active.iter().enumerate() {
                let s = &seqs[i].ctx;
                buf[ai * n_max * p..ai * n_max * p + s.len()].copy_from_slice(s);
            }
            let means = draft.forward_batch(&buf, active.len(), n_max)?;
            for (ai, &i) in active.iter().enumerate() {
                let n_i = seqs[i].ctx.len() / p;
                let off = ai * n_max * p + (n_i - 1) * p;
                let mu_q = means[off..off + p].to_vec();
                let mut x = vec![0.0f32; p];
                seqs[i].rng.fill_normal_around(&mu_q, cfg.policy.sigma as f32, &mut x);
                seqs[i].ctx.extend_from_slice(&x);
                proposals[ai].push(x);
                mu_qs[ai].push(mu_q);
            }
            let _ = step;
        }
        let draft_time = t0.elapsed();

        // --- Target: one batched validation forward.
        let n_max = active.iter().map(|&i| seqs[i].ctx.len() / p).max().unwrap();
        let mut buf = vec![0.0f32; active.len() * n_max * p];
        for (ai, &i) in active.iter().enumerate() {
            let s = &seqs[i].ctx;
            buf[ai * n_max * p..ai * n_max * p + s.len()].copy_from_slice(s);
        }
        let t1 = Instant::now();
        let target_means = target.forward_batch(&buf, active.len(), n_max)?;
        let target_time = t1.elapsed();

        // --- Per-sequence acceptance + emission.
        for (ai, &i) in active.iter().enumerate() {
            let base = ai * n_max * p;
            let n0_i = n0[ai];
            let mu_p_at = |k: usize| &target_means[base + (n0_i - 1 + k) * p..base + (n0_i + k) * p];

            // Per-sequence gamma: a sequence near its horizon only consumes
            // the proposals it can still emit (the round's extra draft work
            // is lockstep overhead, but acceptance statistics stay honest —
            // without this, tail truncation deflates measured E[L]).
            let g_i = gamma.min(seqs[i].remaining().saturating_sub(1));
            let mut alphas = Vec::with_capacity(g_i);
            let mut accepted = 0usize;
            let mut rejected_at = None;
            for k in 0..g_i {
                let a = cfg.policy.alpha(&proposals[ai][k], mu_p_at(k), &mu_qs[ai][k]);
                alphas.push(a);
                if a >= 1.0 || seqs[i].rng.uniform() < a {
                    accepted += 1;
                } else {
                    rejected_at = Some(k);
                    break;
                }
            }
            // Truncate context to the accepted prefix, then re-extend with
            // the emitted values (samples or draft means per protocol).
            seqs[i].ctx.truncate(n0_i * p);
            let mut emit: Vec<f32> = Vec::with_capacity((accepted + 1) * p);
            for k in 0..accepted {
                let patch: &[f32] = match cfg.emission {
                    Emission::Sampled => &proposals[ai][k],
                    Emission::Mean => &mu_qs[ai][k],
                };
                emit.extend_from_slice(patch);
                seqs[i].ctx.extend_from_slice(patch);
            }
            let mut residual_draws = 0usize;
            let final_mu: Vec<f32> = match rejected_at {
                None => mu_p_at(g_i).to_vec(),
                Some(k) => mu_p_at(k).to_vec(),
            };
            let final_patch = match (rejected_at, cfg.variant) {
                (Some(k), Variant::Lossless) => {
                    let mu_q = &mu_qs[ai][k];
                    let sigma = cfg.policy.sigma;
                    let mut z = vec![0.0f32; p];
                    loop {
                        residual_draws += 1;
                        seqs[i].rng.fill_normal_around(&final_mu, sigma as f32, &mut z);
                        let lqp = crate::gaussian::iso_log_ratio(&z, mu_q, &final_mu, sigma);
                        let pi = 1.0 - lqp.min(0.0).exp();
                        if seqs[i].rng.uniform() < pi || residual_draws >= cfg.max_residual_draws {
                            break;
                        }
                    }
                    z
                }
                _ => match cfg.emission {
                    Emission::Sampled => {
                        let mut z = vec![0.0f32; p];
                        seqs[i]
                            .rng
                            .fill_normal_around(&final_mu, cfg.policy.sigma as f32, &mut z);
                        z
                    }
                    Emission::Mean => final_mu,
                },
            };
            emit.extend_from_slice(&final_patch);
            seqs[i].ctx.extend_from_slice(&final_patch);

            // accepted <= g_i <= remaining - 1, so take never truncates now;
            // keep the min as a defensive invariant.
            let take = (accepted + 1).min(seqs[i].remaining());
            debug_assert_eq!(take, accepted + 1);
            seqs[i].out.extend_from_slice(&emit[..take * p]);
            seqs[i].emitted += take;

            let r = RoundStats {
                gamma: g_i,
                accepted,
                emitted: take,
                alphas,
                residual_draws,
                draft_time: draft_time / active.len() as u32,
                target_time: target_time / active.len() as u32,
            };
            seqs[i].stats.absorb(&r);
            seqs[i].rounds.push(r);
        }
    }

    Ok(seqs
        .into_iter()
        .map(|s| DecodeOutput { patches: s.out, rounds: s.rounds, stats: s.stats })
        .collect())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::accept::AcceptancePolicy;
    use crate::models::AnalyticBackend;

    fn cfg(gamma: usize, sigma: f64, seed: u64) -> SpecConfig {
        SpecConfig {
            gamma,
            policy: AcceptancePolicy::new(sigma, 1.0),
            variant: Variant::Practical,
            seed,
            max_residual_draws: 1000,
            emission: Emission::Sampled,
        }
    }

    #[test]
    fn batch_emits_exact_horizons() {
        let t = AnalyticBackend::new("t", 2, 0.8, 0.1);
        let d = AnalyticBackend::new("d", 2, 0.75, 0.1);
        let h1 = vec![0.5f32, -0.5];
        let h2 = vec![1.0f32, 0.0, 0.3, 0.3]; // 2 history patches
        let tasks: Vec<(&[f32], usize, usize)> =
            vec![(&h1, 1, 5), (&h2, 2, 9), (&h1, 1, 1)];
        let outs = sd_generate_batch(&t, &d, &tasks, &cfg(3, 0.5, 1)).unwrap();
        assert_eq!(outs[0].patches.len(), 5 * 2);
        assert_eq!(outs[1].patches.len(), 9 * 2);
        assert_eq!(outs[2].patches.len(), 1 * 2);
    }

    #[test]
    fn batch_of_one_matches_single_path_statistically() {
        // Same seed derivation differs, so compare aggregate acceptance
        // rather than exact values: identical models accept everything in
        // both paths.
        let t = AnalyticBackend::new("t", 2, 0.8, 0.1);
        let d = AnalyticBackend::new("d", 2, 0.8, 0.1);
        let h = vec![0.5f32, -0.5];
        let tasks: Vec<(&[f32], usize, usize)> = vec![(&h, 1, 12)];
        let outs = sd_generate_batch(&t, &d, &tasks, &cfg(3, 0.5, 2)).unwrap();
        assert_eq!(outs[0].stats.accepted, outs[0].stats.proposals);
        assert!((outs[0].stats.alpha_hat() - 1.0).abs() < 1e-9);
    }

    #[test]
    fn sequences_independent() {
        // A hostile sequence in the batch must not change another
        // sequence's acceptance behaviour (only its own).
        let t = AnalyticBackend::new("t", 1, 0.8, 0.0);
        let d = AnalyticBackend::new("d", 1, 0.8, 0.0);
        let good = vec![0.5f32];
        let tasks1: Vec<(&[f32], usize, usize)> = vec![(&good, 1, 10)];
        let solo = sd_generate_batch(&t, &d, &tasks1, &cfg(3, 0.4, 7)).unwrap();
        let weird = vec![99.0f32];
        let tasks2: Vec<(&[f32], usize, usize)> = vec![(&good, 1, 10), (&weird, 1, 10)];
        let pair = sd_generate_batch(&t, &d, &tasks2, &cfg(3, 0.4, 7)).unwrap();
        // Seq 0 has the same seed and same models in both runs.
        assert_eq!(solo[0].patches, pair[0].patches);
    }

    #[test]
    fn heterogeneous_lengths_are_padded_correctly() {
        // Mixed n_hist in one batch: results must equal the single-sequence
        // engine's acceptance pattern for identical models (all-accept).
        let t = AnalyticBackend::new("t", 1, 0.9, 0.05);
        let d = AnalyticBackend::new("d", 1, 0.9, 0.05);
        let h1 = vec![0.1f32];
        let h2 = vec![0.1f32, 0.2, 0.3, 0.4, 0.5];
        let tasks: Vec<(&[f32], usize, usize)> = vec![(&h1, 1, 6), (&h2, 5, 6)];
        let outs = sd_generate_batch(&t, &d, &tasks, &cfg(2, 0.5, 3)).unwrap();
        for o in &outs {
            assert_eq!(o.stats.accepted, o.stats.proposals, "identical heads must accept");
            assert_eq!(o.patches.len(), 6);
            assert!(o.patches.iter().all(|v| v.is_finite()));
        }
    }
}
