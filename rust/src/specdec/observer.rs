//! Per-round observation hook: lets the serving tier watch every
//! speculative round as it completes without the engines knowing about
//! servers, trace sinks, or request ids.
//!
//! The engines ([`super::sd_generate_from`], the tree and batched
//! variants) call [`notify_round`] right after constructing each
//! [`RoundStats`]. The hook is a thread-local `Option<Arc<dyn
//! RoundObserver>>` installed for the dynamic extent of one decode by
//! [`with_round_observer`]: the scheduler (which runs each decode group
//! synchronously on a replica thread) installs an observer that maps the
//! sequence index back to a request id and forwards the round into the
//! flight recorder ([`crate::trace`]).
//!
//! Cost discipline: with no observer installed (the default, and always
//! the case when tracing is off) `notify_round` is one TLS access and a
//! `None` check — no allocation, no locking, and no effect on decode
//! output, preserving the engines' bit-identity walls. The installer is
//! panic-safe: the previous observer is restored by a drop guard even if
//! the decode unwinds (replica panics are supervised and must not leak a
//! stale observer into the replica's next decode).

use std::cell::RefCell;
use std::sync::Arc;

use super::stats::RoundStats;

/// A sink for completed speculation rounds. `seq` is the in-batch
/// sequence index (0 for single-sequence decodes; the lockstep batched
/// engine passes each sequence's slot index).
pub trait RoundObserver: Send + Sync {
    /// Called synchronously after round `round`'s stats are final, on
    /// the decoding thread. Implementations must be cheap and must not
    /// call back into the engines.
    fn on_round(&self, seq: usize, round: &RoundStats);
}

thread_local! {
    static OBSERVER: RefCell<Option<Arc<dyn RoundObserver>>> = RefCell::new(None);
}

/// Install `obs` as this thread's round observer for the duration of
/// `f`, restoring the previous observer (usually `None`) afterwards —
/// including on unwind.
pub fn with_round_observer<R>(obs: Arc<dyn RoundObserver>, f: impl FnOnce() -> R) -> R {
    struct Guard(Option<Arc<dyn RoundObserver>>);
    impl Drop for Guard {
        fn drop(&mut self) {
            let prev = self.0.take();
            OBSERVER.with(|o| *o.borrow_mut() = prev);
        }
    }
    let prev = OBSERVER.with(|o| o.borrow_mut().replace(obs));
    let _restore = Guard(prev);
    f()
}

/// Engine-side notification point: forwards `round` to the installed
/// observer, if any. One TLS borrow + `None` check when tracing is off.
#[inline]
pub(crate) fn notify_round(seq: usize, round: &RoundStats) {
    OBSERVER.with(|o| {
        if let Some(obs) = o.borrow().as_ref() {
            obs.on_round(seq, round);
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Mutex;
    use std::time::Duration;

    fn round(gamma: usize, accepted: usize) -> RoundStats {
        RoundStats {
            gamma,
            accepted,
            emitted: accepted + 1,
            alphas: vec![0.5; gamma],
            residual_draws: usize::from(accepted < gamma),
            branches: 1,
            draft_time: Duration::from_micros(10),
            target_time: Duration::from_micros(40),
        }
    }

    struct Collect(Mutex<Vec<(usize, usize, usize)>>);
    impl RoundObserver for Collect {
        fn on_round(&self, seq: usize, r: &RoundStats) {
            self.0.lock().unwrap().push((seq, r.gamma, r.accepted));
        }
    }

    #[test]
    fn observer_sees_rounds_only_inside_scope() {
        let obs = Arc::new(Collect(Mutex::new(Vec::new())));
        notify_round(0, &round(4, 2)); // no observer installed: dropped
        let got = with_round_observer(obs.clone(), || {
            notify_round(0, &round(4, 4));
            notify_round(1, &round(2, 0));
            42
        });
        assert_eq!(got, 42);
        notify_round(0, &round(8, 8)); // outside again: dropped
        assert_eq!(*obs.0.lock().unwrap(), vec![(0, 4, 4), (1, 2, 0)]);
    }

    #[test]
    fn observer_restored_after_panic() {
        let outer = Arc::new(Collect(Mutex::new(Vec::new())));
        with_round_observer(outer.clone(), || {
            let inner = Arc::new(Collect(Mutex::new(Vec::new())));
            let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                with_round_observer(inner, || panic!("replica fault"));
            }));
            assert!(r.is_err());
            // The outer observer must be back in place after the unwind.
            notify_round(3, &round(1, 1));
        });
        assert_eq!(*outer.0.lock().unwrap(), vec![(3, 1, 1)]);
    }

    #[test]
    fn nested_installs_shadow_and_restore() {
        let a = Arc::new(Collect(Mutex::new(Vec::new())));
        let b = Arc::new(Collect(Mutex::new(Vec::new())));
        with_round_observer(a.clone(), || {
            notify_round(0, &round(1, 0));
            with_round_observer(b.clone(), || notify_round(0, &round(2, 1)));
            notify_round(0, &round(3, 2));
        });
        assert_eq!(*a.0.lock().unwrap(), vec![(0, 1, 0), (0, 3, 2)]);
        assert_eq!(*b.0.lock().unwrap(), vec![(0, 2, 1)]);
    }
}
