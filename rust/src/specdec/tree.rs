//! Tree speculation: k candidate draft trajectories per round, longest
//! accepted branch committed.
//!
//! The paper verifies a single draft trajectory per speculative round;
//! Speculative Streaming (Bhendawade et al.) and SpecDec (Xia et al.)
//! showed that verifying *k* candidate continuations of the same prefix
//! materially lengthens the accepted run — a rejection on one branch no
//! longer ends the round if a sibling survived deeper. This module
//! generalizes the engine along that axis:
//!
//! 1. **Draft**: the source produces k candidate blocks per round via
//!    [`super::draft::DraftSource::propose_k`] — k distinct sample paths
//!    for a model-backed draft, k σ-perturbed continuations for the
//!    closed-form sources. All branches fork the *committed* prefix.
//! 2. **Verify**: all k branch suffixes are validated by **one stacked
//!    target forward** against the shared-prefix KV cache
//!    (`DecodeSession::verify_stacked`, kernel-layer sessions only) —
//!    every GEMM in the round spans k·γ rows, and the session is never
//!    mutated. Sessions without a stacked kernel — and rounds with
//!    [`set_stacked_verify`] off — take the retained *sequential
//!    reference path*: one target `extend` per branch, forked between
//!    branches by `rollback(γ)`. The two paths are bitwise identical
//!    (`tests/tree_equivalence.rs`'s stacked wall).
//! 3. **Commit**: each branch runs the standard acceptance scan (its own
//!    uniforms, in branch order); the branch with the longest accepted
//!    run wins (ties to the lowest index), its accepted prefix is
//!    committed under the usual emission protocol, and the final
//!    bonus/fallback patch comes from the winner's target rows.
//!
//! **The k = 1 equivalence wall.** At `k = 1` every step above collapses
//! to the classic loop — same RNG stream, same session-operation
//! sequence, same emitted bits (`tests/tree_equivalence.rs` pins this
//! across backends × cache × variants × emissions). That wall is why the
//! lossless variant is *restricted* to k = 1: Theorems 1–2 are statements
//! about the single-trajectory chain, and picking the argmax of k
//! acceptance scans re-weights the emitted law in a way the residual
//! coupling does not correct. `k > 1` therefore requires
//! [`Variant::Practical`]; a lossless request with `k > 1` (or an
//! adaptive controller allowed to choose `k > 1`) is a validation error,
//! never a silent clamp.
//!
//! Expected block length generalizes Eq. 4 to
//! `E[L_k] = 1 + Σ_{i=1..γ} (1 − (1 − αⁱ)^k)` (independent-branch
//! approximation, [`crate::theory::expected_block_length_tree`]), and the
//! Eq. 5 trade-off picks up a k-multiplied draft cost:
//! `S = E[L_k] / (c·k·γ + 1)` ([`crate::theory::tree_wall_speedup`]) —
//! the 2-D (γ × k) surface the [`super::GammaController`] scans when
//! `adaptive.k_max > 1`.

use std::sync::atomic::{AtomicBool, Ordering};
use std::time::Instant;

use anyhow::Result;

use super::controller::GammaController;
use super::draft::{make_source, DraftSource, RoundFeedback};
use super::engine::{emit_from_p, residual_thin, Emission, GammaPlan, SpecConfig, Variant};
use super::stats::{DecodeOutput, DecodeStats, RoundStats};
use crate::models::{begin_session, Backend};
use crate::util::rng::Rng;

/// Hard cap on the branch count — k·γ proposals are drafted and verified
/// per round, so k is a cost multiplier; 16 is far past the point where
/// Eq. 5's `c·k·γ + 1` denominator eats the E\[L\] gain.
pub const MAX_TREE_K: usize = 16;

/// Route k > 1 verify rounds through `DecodeSession::verify_stacked`
/// (one stacked target forward for all branches) instead of the
/// sequential per-branch extend/rollback loop. Default **on**; the two
/// paths are bitwise identical (`tests/tree_equivalence.rs`'s stacked
/// wall), so this toggle exists for the wall itself and for the
/// before/after benches, following the `set_reference_kernel` /
/// `set_scalar_kernel` precedent.
static STACKED_VERIFY: AtomicBool = AtomicBool::new(true);

/// Enable or disable the stacked (one-forward) tree verify path.
pub fn set_stacked_verify(on: bool) {
    STACKED_VERIFY.store(on, Ordering::SeqCst);
}

/// Whether k > 1 rounds attempt the stacked verify path (default true).
pub fn stacked_verify_enabled() -> bool {
    STACKED_VERIFY.load(Ordering::SeqCst)
}

/// [`super::sd_generate`] with tree speculation: `cfg.k` candidate
/// branches per round, longest accepted branch committed. At
/// `cfg.k == 1` this is bit-identical to [`super::sd_generate`].
pub fn sd_generate_tree(
    target: &dyn Backend,
    draft: &dyn Backend,
    history: &[f32],
    n_hist: usize,
    horizon: usize,
    cfg: &SpecConfig,
) -> Result<DecodeOutput> {
    anyhow::ensure!(target.patch() == draft.patch(), "patch mismatch");
    let mut source = make_source(&cfg.draft, draft)?;
    sd_generate_tree_from(target, source.as_mut(), history, n_hist, horizon, cfg)
}

/// [`sd_generate_tree`] over a caller-owned [`DraftSource`] (the source
/// keeps its learned state across calls, as in
/// [`super::sd_generate_from`]).
pub fn sd_generate_tree_from(
    target: &dyn Backend,
    source: &mut dyn DraftSource,
    history: &[f32],
    n_hist: usize,
    horizon: usize,
    cfg: &SpecConfig,
) -> Result<DecodeOutput> {
    match cfg.adaptive {
        Some(acfg) => {
            acfg.validate()?;
            if cfg.variant == Variant::Lossless {
                anyhow::ensure!(
                    cfg.k == 1 && acfg.k_max == 1,
                    "lossless exactness is only proven for decodes bit-identical \
                     to k = 1; tree speculation (k > 1 or adaptive.k_max > 1) \
                     requires Variant::Practical"
                );
            }
            let mut ctrl = GammaController::new(acfg, cfg.gamma, cfg.policy.sigma);
            ctrl.seed_k(cfg.k);
            sd_generate_tree_ctrl(target, source, history, n_hist, horizon, cfg, &mut ctrl)
        }
        None => sd_generate_tree_impl(
            target,
            source,
            history,
            n_hist,
            horizon,
            cfg,
            &mut GammaPlan::Fixed,
        ),
    }
}

/// Tree decode driven by a caller-owned controller (invoked by
/// [`super::sd_generate_from_with_controller`] whenever the decode might
/// run a k > 1 round). Lossless compatibility is validated by the caller.
pub(super) fn sd_generate_tree_ctrl(
    target: &dyn Backend,
    source: &mut dyn DraftSource,
    history: &[f32],
    n_hist: usize,
    horizon: usize,
    cfg: &SpecConfig,
    ctrl: &mut GammaController,
) -> Result<DecodeOutput> {
    ctrl.config().validate()?;
    sd_generate_tree_impl(
        target,
        source,
        history,
        n_hist,
        horizon,
        cfg,
        &mut GammaPlan::Controller(ctrl),
    )
}

/// The tree decode loop. Structured as [`super::sd_generate`]'s loop with
/// the propose/verify/commit stages generalized over branches; every
/// k = 1 round performs the classic loop's exact session-op and RNG
/// sequence (the equivalence wall).
fn sd_generate_tree_impl(
    target: &dyn Backend,
    source: &mut dyn DraftSource,
    history: &[f32],
    n_hist: usize,
    horizon: usize,
    cfg: &SpecConfig,
    plan: &mut GammaPlan<'_>,
) -> Result<DecodeOutput> {
    let p = target.patch();
    anyhow::ensure!(p == source.patch(), "patch mismatch");
    anyhow::ensure!(n_hist >= 1, "need at least one history patch");
    anyhow::ensure!(history.len() >= n_hist * p, "history too short");
    anyhow::ensure!(cfg.gamma >= 1, "gamma >= 1");
    anyhow::ensure!(
        cfg.k >= 1 && cfg.k <= MAX_TREE_K,
        "k must be in [1, {MAX_TREE_K}], got {}",
        cfg.k
    );
    if cfg.variant == Variant::Lossless {
        anyhow::ensure!(
            cfg.k == 1,
            "lossless exactness is only proven for decodes bit-identical \
             to k = 1; tree speculation (k > 1) requires Variant::Practical"
        );
        anyhow::ensure!(
            (cfg.policy.bias - 1.0).abs() < 1e-12,
            "lossless exactness requires canonical acceptance (bias = 1)"
        );
        anyhow::ensure!(
            cfg.emission == Emission::Sampled,
            "lossless exactness (Theorems 1-2) is a statement about the \
             sampled chain; use Emission::Sampled"
        );
    }

    let max_ctx = target.max_ctx().min(source.max_ctx());
    anyhow::ensure!(
        cfg.gamma + 1 < max_ctx,
        "gamma {} cannot fit the joint context window: a round appends \
         gamma + 1 patches and must keep at least one context patch \
         (target max_ctx {}, draft max_ctx {}) — lower gamma or raise \
         the binding side's context",
        cfg.gamma,
        target.max_ctx(),
        source.max_ctx()
    );

    let mut rng = Rng::new(cfg.seed);
    let keep0 = n_hist.min(max_ctx);
    let hist = &history[(n_hist - keep0) * p..n_hist * p];
    let mut t_sess = begin_session(target, cfg.cache, hist, keep0)?;
    source.begin(hist, keep0, cfg.cache)?;
    let upd0 = source.updates();
    let mut emitted = 0usize;
    let mut out_patches: Vec<f32> = Vec::with_capacity(horizon * p);
    let mut rounds = Vec::new();
    let mut stats = DecodeStats::default();
    // Round-reused buffers for the stacked verify path: the flat
    // [k, gamma, patch] branch block and the [k, gamma+1, patch] result
    // rows. Grown once to the round high-water mark, then steady-state
    // stacked rounds allocate nothing here.
    let mut stacked_flat: Vec<f32> = Vec::new();
    let mut stacked_rows: Vec<f32> = Vec::new();

    while emitted < horizon {
        let remaining = horizon - emitted;
        let gamma = plan.desired(cfg, max_ctx).min(remaining.saturating_sub(1));
        let policy = plan.policy(cfg);

        // Window slide: branches are verified one at a time against the
        // shared prefix (fork = rollback), so the peak in-session length
        // is the classic gamma + 1 regardless of k.
        let need = gamma + 1;
        let n_ctx_now = t_sess.len();
        if n_ctx_now + need > max_ctx {
            anyhow::ensure!(need < max_ctx, "gamma {gamma} cannot fit in max_ctx {max_ctx}");
            let keep = max_ctx - need;
            t_sess.evict_to(keep)?;
            source.evict_to(keep)?;
        }

        if gamma == 0 {
            // Horizon tail: plain target AR step — no proposals, so no
            // branches either (identical to the classic tail).
            let t0 = Instant::now();
            let mu_p = t_sess.tip_mean()?;
            super::engine::ensure_finite(&mu_p, "target tip mean")?;
            let patch = emit_from_p(&mu_p, policy.sigma, cfg.emission, &mut rng);
            t_sess.append(&patch, 1)?;
            let tt = t0.elapsed();
            let t1 = Instant::now();
            source.append(&patch, 1)?;
            let dt = t1.elapsed();
            out_patches.extend_from_slice(&patch);
            emitted += 1;
            let r = RoundStats {
                gamma: 0,
                accepted: 0,
                emitted: 1,
                alphas: vec![],
                residual_draws: 0,
                branches: 1,
                draft_time: dt,
                target_time: tt,
            };
            plan.observe(&r);
            super::observer::notify_round(0, &r);
            stats.absorb(&r);
            rounds.push(r);
            continue;
        }

        let k_round = plan.k_for(cfg).clamp(1, MAX_TREE_K);

        // --- Draft k candidate branches, all forking the committed
        // prefix, branch j's samples drawn after branch j-1's on the one
        // decode RNG stream (so branch 0 ≡ the k = 1 draft).
        let t0 = Instant::now();
        let blocks = source.propose_k(gamma, k_round, policy.sigma, &mut rng)?;
        let mut draft_time = t0.elapsed();
        anyhow::ensure!(
            blocks.len() == k_round,
            "draft source returned {} branches for k {k_round}",
            blocks.len()
        );
        for b in &blocks {
            anyhow::ensure!(
                b.proposals.len() == gamma && b.mu_qs.len() == gamma,
                "draft source returned {} proposals for gamma {gamma}",
                b.proposals.len()
            );
            for (x, m) in b.proposals.iter().zip(&b.mu_qs) {
                super::engine::ensure_finite(x, "draft proposal")?;
                super::engine::ensure_finite(m, "draft mean")?;
            }
        }

        // --- Verify. Preferred path for k > 1: ONE stacked target
        // forward over all k branch suffixes against the shared-prefix
        // KV cache (`DecodeSession::verify_stacked`), leaving the
        // session untouched at the prefix. Sessions without a stacked
        // kernel (stateless, analytic, reference mode) return false and
        // fall back to the sequential reference path: one extend per
        // branch with rollback(γ) forking the next branch off the same
        // cached prefix. Both paths produce bit-identical rows (the
        // stacked wall in `tests/tree_equivalence.rs`); verify consumes
        // no RNG either way, so the acceptance scans below see the same
        // uniform stream regardless of path.
        let t1 = Instant::now();
        let mut branch_rows: Vec<Vec<f32>> = Vec::new();
        let mut stacked_used = false;
        if k_round > 1 && stacked_verify_enabled() {
            stacked_flat.clear();
            for b in &blocks {
                for x in &b.proposals {
                    stacked_flat.extend_from_slice(x);
                }
            }
            stacked_used = t_sess.verify_stacked(&stacked_flat, k_round, gamma, &mut stacked_rows)?;
            if stacked_used {
                let per = (gamma + 1) * p;
                for j in 0..k_round {
                    super::engine::ensure_finite(
                        &stacked_rows[j * per..(j + 1) * per],
                        "target validation means",
                    )?;
                }
            }
        }
        if !stacked_used {
            branch_rows.reserve(k_round);
            for (j, b) in blocks.iter().enumerate() {
                let mut flat = Vec::with_capacity(gamma * p);
                for x in &b.proposals {
                    flat.extend_from_slice(x);
                }
                let rows = t_sess.extend(&flat, gamma)?;
                super::engine::ensure_finite(&rows, "target validation means")?;
                branch_rows.push(rows);
                if j + 1 < k_round {
                    t_sess.rollback(gamma)?;
                }
            }
        }
        let mut target_time = t1.elapsed();

        // --- Acceptance scan per branch, in branch order, each branch
        // consuming its own uniforms (at k = 1 this is the classic scan
        // at the classic stream position). `all_alphas` collects every
        // evaluated probability for stats; the winner's own alphas feed
        // the draft source.
        // Branch j's γ+1 result rows, independent of which verify path
        // ran: a slice of the stacked block, or the j-th sequential
        // extend's return.
        let rows_of = |j: usize| -> &[f32] {
            if stacked_used {
                &stacked_rows[j * (gamma + 1) * p..(j + 1) * (gamma + 1) * p]
            } else {
                &branch_rows[j]
            }
        };

        let mut all_alphas: Vec<f64> = Vec::new();
        let mut scans: Vec<(usize, Option<usize>, Vec<f64>)> = Vec::with_capacity(k_round);
        for (j, b) in blocks.iter().enumerate() {
            let rows = rows_of(j);
            let mut alphas = Vec::with_capacity(gamma);
            let mut accepted = 0usize;
            let mut rejected_at: Option<usize> = None;
            for i in 0..gamma {
                let a = policy.alpha(&b.proposals[i], &rows[i * p..(i + 1) * p], &b.mu_qs[i]);
                alphas.push(a);
                if a >= 1.0 || rng.uniform() < a {
                    accepted += 1;
                } else {
                    rejected_at = Some(i);
                    break;
                }
            }
            all_alphas.extend_from_slice(&alphas);
            scans.push((accepted, rejected_at, alphas));
        }

        // --- Winner: longest accepted run, ties to the lowest branch
        // index (so k = 1 trivially selects branch 0 and identical
        // branches behave deterministically).
        let winner = (0..k_round).max_by_key(|&j| (scans[j].0, usize::MAX - j)).unwrap_or(0);
        let (accepted, rejected_at, win_alphas) = scans[winner].clone();
        let wblock = &blocks[winner];
        let wrows = rows_of(winner);
        let mu_p_at = |i: usize| &wrows[i * p..(i + 1) * p];

        // --- Commit the winner under the usual emission protocol. After
        // a *stacked* verify the session still sits at the shared prefix,
        // so committing is a plain append — the recomputed K/V and mean
        // rows are bitwise those of the verify pass (deterministic
        // row-independent kernels). After a *sequential* verify the
        // session holds the last branch's proposals; when the winner is
        // that branch the classic in-place ops apply verbatim, otherwise
        // rewind fully and rebuild from the winner's patches.
        let mut emit_flat: Vec<f32> = Vec::with_capacity(accepted * p);
        match cfg.emission {
            Emission::Sampled => {
                for x in &wblock.proposals[..accepted] {
                    emit_flat.extend_from_slice(x);
                }
                let t2 = Instant::now();
                if stacked_used {
                    if accepted > 0 {
                        t_sess.append(&emit_flat, accepted)?;
                    }
                } else if winner == k_round - 1 {
                    t_sess.rollback(gamma - accepted)?;
                } else {
                    t_sess.rollback(gamma)?;
                    if accepted > 0 {
                        t_sess.append(&emit_flat, accepted)?;
                    }
                }
                target_time += t2.elapsed();
            }
            Emission::Mean => {
                for m in &wblock.mu_qs[..accepted] {
                    emit_flat.extend_from_slice(m);
                }
                let t2 = Instant::now();
                if !stacked_used {
                    t_sess.rollback(gamma)?;
                }
                if accepted > 0 {
                    t_sess.append(&emit_flat, accepted)?;
                }
                target_time += t2.elapsed();
            }
        }
        out_patches.extend_from_slice(&emit_flat);

        let mut residual_draws = 0usize;
        let final_patch: Vec<f32> = match rejected_at {
            None => emit_from_p(mu_p_at(gamma), policy.sigma, cfg.emission, &mut rng),
            Some(i) => match cfg.variant {
                Variant::Practical => {
                    emit_from_p(mu_p_at(i), policy.sigma, cfg.emission, &mut rng)
                }
                // Reachable only at k = 1 (validated above), where this
                // is the classic lossless residual draw.
                Variant::Lossless => {
                    let (z, draws) = residual_thin(
                        mu_p_at(i),
                        &wblock.mu_qs[i],
                        policy.sigma,
                        cfg.max_residual_draws,
                        &mut rng,
                    );
                    residual_draws = draws;
                    z
                }
            },
        };
        out_patches.extend_from_slice(&final_patch);
        let t6 = Instant::now();
        t_sess.append(&final_patch, 1)?;
        target_time += t6.elapsed();

        // --- Feed the winner back to the source (its alphas, its target
        // rows, the committed patches): exactly the classic feedback at
        // k = 1; for tree rounds the source rebuilds from the committed
        // block since all branches were rolled back during drafting.
        let t7 = Instant::now();
        source.finish_round(&RoundFeedback {
            gamma,
            accepted,
            alphas: &win_alphas,
            target_means: wrows,
            committed: &emit_flat,
            final_patch: &final_patch,
            sampled: cfg.emission == Emission::Sampled,
        })?;
        draft_time += t7.elapsed();

        emitted += accepted + 1;

        let r = RoundStats {
            gamma,
            accepted,
            emitted: accepted + 1,
            alphas: all_alphas,
            residual_draws,
            branches: k_round,
            draft_time,
            target_time,
        };
        plan.observe(&r);
        super::observer::notify_round(0, &r);
        stats.absorb(&r);
        rounds.push(r);
    }

    out_patches.truncate(horizon * p);
    stats.draft_updates = source.updates().saturating_sub(upd0);
    Ok(DecodeOutput { patches: out_patches, rounds, stats })
}

#[cfg(test)]
mod tests {
    use super::super::{sd_generate, DraftConfig, DraftKind};
    use super::*;
    use crate::accept::AcceptancePolicy;
    use crate::models::{AnalyticBackend, CacheMode};

    fn cfg(gamma: usize, k: usize, sigma: f64, variant: Variant, seed: u64) -> SpecConfig {
        SpecConfig {
            gamma,
            k,
            policy: AcceptancePolicy::new(sigma, 1.0),
            variant,
            seed,
            max_residual_draws: 10_000,
            emission: Emission::Sampled,
            cache: CacheMode::On,
            draft: DraftConfig::default(),
            adaptive: None,
        }
    }

    #[test]
    fn k1_tree_is_bitwise_identical_to_classic() {
        let t = AnalyticBackend::new("t", 2, 0.8, 0.1);
        let d = AnalyticBackend::new("d", 2, 0.7, 0.15);
        let hist = [0.5f32, -0.5, 0.2, 0.1, -0.3, 0.4];
        for variant in [Variant::Practical, Variant::Lossless] {
            for emission in [Emission::Mean, Emission::Sampled] {
                if variant == Variant::Lossless && emission == Emission::Mean {
                    continue;
                }
                let mut c = cfg(3, 1, 0.4, variant, 77);
                c.emission = emission;
                let classic = sd_generate(&t, &d, &hist, 3, 13, &c).unwrap();
                let tree = sd_generate_tree(&t, &d, &hist, 3, 13, &c).unwrap();
                let cb: Vec<u32> = classic.patches.iter().map(|v| v.to_bits()).collect();
                let tb: Vec<u32> = tree.patches.iter().map(|v| v.to_bits()).collect();
                assert_eq!(cb, tb, "{variant:?}/{emission:?}");
                assert_eq!(classic.stats.accepted, tree.stats.accepted);
                assert_eq!(classic.stats.rounds, tree.stats.rounds);
                assert_eq!(classic.stats.branches_verified, tree.stats.branches_verified);
            }
        }
    }

    #[test]
    fn k_gt1_decodes_exact_horizon_and_records_branches() {
        let t = AnalyticBackend::new("t", 2, 0.8, 0.1);
        let d = AnalyticBackend::new("d", 2, 0.6, 0.3); // imperfect draft
        for kind in [DraftKind::Model, DraftKind::Extrap, DraftKind::Adaptive] {
            for k in [2usize, 4] {
                let mut c = cfg(3, k, 0.4, Variant::Practical, 5);
                c.draft.kind = kind;
                let out = sd_generate_tree(&t, &d, &[0.5, -0.5, 0.2, 0.1], 2, 17, &c).unwrap();
                assert_eq!(out.patches.len(), 17 * 2, "{kind:?} k={k}");
                assert!(out.patches.iter().all(|v| v.is_finite()));
                assert_eq!(out.stats.sum_block_len, 17);
                // Every proposal round verified k branches.
                for r in out.rounds.iter().filter(|r| r.gamma > 0) {
                    assert_eq!(r.branches, k);
                    assert!(r.accepted <= r.gamma, "block length bound");
                }
                let prop_rounds = out.rounds.iter().filter(|r| r.gamma > 0).count();
                let tail_rounds = out.rounds.len() - prop_rounds;
                assert_eq!(out.stats.branches_verified, prop_rounds * k + tail_rounds);
            }
        }
    }

    #[test]
    fn winner_run_lengthens_with_k_on_average() {
        // Max-of-k accepted runs stochastically dominates the single
        // run, so the first-round mean accepted length must rise from
        // k=1 to k=4 over many seeds (rigorous many-seed versions live
        // in tests/statistical.rs and the tree_speculation bench).
        let t = AnalyticBackend::new("t", 1, 0.7, 0.2);
        let d = AnalyticBackend::new("d", 1, 0.5, 0.1);
        let (mut sum1, mut sum4) = (0usize, 0usize);
        for seed in 0..60u64 {
            let c1 = cfg(4, 1, 0.5, Variant::Practical, seed);
            let c4 = cfg(4, 4, 0.5, Variant::Practical, seed);
            let o1 = sd_generate_tree(&t, &d, &[0.8], 1, 25, &c1).unwrap();
            let o4 = sd_generate_tree(&t, &d, &[0.8], 1, 25, &c4).unwrap();
            sum1 += o1.rounds[0].accepted;
            sum4 += o4.rounds[0].accepted;
        }
        assert!(
            sum4 > sum1,
            "k=4 first-round accepted sum {sum4} should beat k=1 sum {sum1}"
        );
    }

    #[test]
    fn lossless_rejects_k_gt1() {
        let t = AnalyticBackend::new("t", 1, 0.8, 0.0);
        let d = AnalyticBackend::new("d", 1, 0.7, 0.0);
        let c = cfg(2, 2, 0.5, Variant::Lossless, 1);
        let err = sd_generate_tree(&t, &d, &[0.0], 1, 4, &c).unwrap_err();
        assert!(format!("{err:#}").contains("Practical"), "{err:#}");
        // k = 1 lossless decodes fine through the tree path.
        let c1 = cfg(2, 1, 0.5, Variant::Lossless, 1);
        assert!(sd_generate_tree(&t, &d, &[0.0], 1, 4, &c1).is_ok());
    }

    #[test]
    fn k_cap_enforced() {
        let t = AnalyticBackend::new("t", 1, 0.8, 0.0);
        let d = AnalyticBackend::new("d", 1, 0.7, 0.0);
        let c = cfg(2, MAX_TREE_K + 1, 0.5, Variant::Practical, 1);
        assert!(sd_generate_tree(&t, &d, &[0.0], 1, 4, &c).is_err());
    }

    #[test]
    fn stacked_verify_toggle_is_bitwise_invisible() {
        // Native (kernel-layer) sessions take the stacked path when the
        // toggle is on; the emitted stream must be bit-identical either
        // way — the unit-level echo of the tree_equivalence stacked wall.
        use crate::models::NativeBackend;
        use crate::nn::model::tiny_model;
        let t = NativeBackend::new(tiny_model(21));
        let d = NativeBackend::new(tiny_model(22));
        let hist: Vec<f32> = (0..3 * 4).map(|i| (i as f32 * 0.2).sin()).collect();
        let c = cfg(3, 3, 0.4, Variant::Practical, 11);
        set_stacked_verify(true);
        let on = sd_generate_tree(&t, &d, &hist, 3, 15, &c).unwrap();
        set_stacked_verify(false);
        let off = sd_generate_tree(&t, &d, &hist, 3, 15, &c).unwrap();
        set_stacked_verify(true);
        let ob: Vec<u32> = on.patches.iter().map(|v| v.to_bits()).collect();
        let fb: Vec<u32> = off.patches.iter().map(|v| v.to_bits()).collect();
        assert_eq!(ob, fb, "stacked verify changed the emitted bits");
        assert_eq!(on.stats.accepted, off.stats.accepted);
        assert_eq!(on.stats.rounds, off.stats.rounds);
    }

    #[test]
    fn deterministic_given_seed() {
        let t = AnalyticBackend::new("t", 2, 0.8, 0.1);
        let d = AnalyticBackend::new("d", 2, 0.7, 0.1);
        let c = cfg(3, 3, 0.4, Variant::Practical, 42);
        let a = sd_generate_tree(&t, &d, &[0.5, 0.5], 1, 9, &c).unwrap();
        let b = sd_generate_tree(&t, &d, &[0.5, 0.5], 1, 9, &c).unwrap();
        assert_eq!(a.patches, b.patches);
        let mut c2 = c;
        c2.seed = 43;
        let e = sd_generate_tree(&t, &d, &[0.5, 0.5], 1, 9, &c2).unwrap();
        assert_ne!(a.patches, e.patches);
    }

    #[test]
    fn routed_through_sd_generate_when_k_set() {
        // The public entry points route k > 1 configs to the tree loop.
        let t = AnalyticBackend::new("t", 1, 0.8, 0.1);
        let d = AnalyticBackend::new("d", 1, 0.6, 0.2);
        let c = cfg(3, 2, 0.5, Variant::Practical, 3);
        let via_classic_entry = sd_generate(&t, &d, &[0.4], 1, 11, &c).unwrap();
        let via_tree_entry = sd_generate_tree(&t, &d, &[0.4], 1, 11, &c).unwrap();
        assert_eq!(via_classic_entry.patches, via_tree_entry.patches);
        assert!(via_classic_entry.rounds.iter().any(|r| r.branches == 2));
    }
}
