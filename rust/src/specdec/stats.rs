//! Per-round and per-decode statistics: everything the paper's tables
//! report (α̂, E\[L\], measured speedup components) is accumulated here.

use std::time::Duration;

/// One speculative round's outcome.
#[derive(Clone, Debug)]
pub struct RoundStats {
    /// γ actually used this round (may be capped near the horizon end).
    pub gamma: usize,
    /// Accepted consecutive proposals (the run length before rejection).
    pub accepted: usize,
    /// Patches emitted this round (accepted + 1 bonus/fallback).
    pub emitted: usize,
    /// Acceptance probabilities evaluated (one per checked proposal).
    pub alphas: Vec<f64>,
    /// Extra target draws consumed by residual thinning (lossless only).
    pub residual_draws: usize,
    /// Candidate branches drafted and verified this round (1 for the
    /// classic single-trajectory path; k for tree rounds).
    pub branches: usize,
    /// Wall clock spent in draft-model work this round.
    pub draft_time: Duration,
    /// Wall clock spent in target-model work this round.
    pub target_time: Duration,
}

/// Aggregate over a full decode.
#[derive(Clone, Debug, Default)]
pub struct DecodeStats {
    /// Speculative rounds executed.
    pub rounds: usize,
    /// Draft forward passes consumed.
    pub draft_calls: usize,
    /// Target forward passes consumed (incl. residual-draw accounting).
    pub target_calls: usize,
    /// Residual thinning draws across all rejections (lossless only).
    pub residual_draws: usize,
    /// Draft proposals checked by the acceptance rule.
    pub proposals: usize,
    /// Proposals accepted.
    pub accepted: usize,
    /// Sum of evaluated acceptance probabilities (α̂ numerator).
    pub sum_alpha: f64,
    /// Count of evaluated acceptance probabilities (α̂ denominator).
    pub alpha_count: usize,
    /// Sum of emitted patches per round (E\[L\] numerator).
    pub sum_block_len: usize,
    /// Total wall clock in draft-model work.
    pub draft_time: Duration,
    /// Total wall clock in target-model work.
    pub target_time: Duration,
    /// Online draft-parameter updates applied during this decode (0 for
    /// non-learning draft sources; set by the decode loops from
    /// `DraftSource::updates` deltas, not accumulated per round).
    pub draft_updates: usize,
    /// Candidate branches verified across all rounds (equals `rounds`
    /// for classic k = 1 decodes; grows k-fold on tree rounds).
    pub branches_verified: usize,
}

impl DecodeStats {
    /// Fold one round's outcome into the aggregate.
    pub fn absorb(&mut self, r: &RoundStats) {
        // Tree rounds draft and check gamma proposals *per branch*; the
        // classic path sets branches = 1 so the multiplier is inert.
        let fan = r.branches.max(1);
        self.rounds += 1;
        self.draft_calls += r.gamma * fan;
        self.target_calls += fan + r.residual_draws; // one verify extend per branch; residual draws re-use p samples, not forwards
        self.residual_draws += r.residual_draws;
        self.proposals += r.gamma * fan;
        self.branches_verified += fan;
        self.accepted += r.accepted;
        self.sum_alpha += r.alphas.iter().sum::<f64>();
        self.alpha_count += r.alphas.len();
        self.sum_block_len += r.emitted;
        self.draft_time += r.draft_time;
        self.target_time += r.target_time;
    }

    /// Empirical mean acceptance probability (the table's α̂ column).
    pub fn alpha_hat(&self) -> f64 {
        if self.alpha_count == 0 {
            f64::NAN
        } else {
            self.sum_alpha / self.alpha_count as f64
        }
    }

    /// Empirical acceptance *rate* (fraction of proposals accepted).
    pub fn accept_rate(&self) -> f64 {
        if self.proposals == 0 {
            f64::NAN
        } else {
            self.accepted as f64 / self.proposals as f64
        }
    }

    /// Mean emitted patches per round (measured E\[L\]).
    pub fn mean_block_len(&self) -> f64 {
        if self.rounds == 0 {
            f64::NAN
        } else {
            self.sum_block_len as f64 / self.rounds as f64
        }
    }

    /// Measured draft/target cost ratio over this decode: mean draft
    /// wall clock per proposal relative to mean target wall clock per
    /// validation round — the paper's c, in the same convention the
    /// adaptive controller measures it. Near zero for draft-free
    /// sources. NaN until both clocks have ticked.
    pub fn cost_ratio(&self) -> f64 {
        if self.proposals == 0 || self.rounds == 0 {
            return f64::NAN;
        }
        let per_prop = self.draft_time.as_secs_f64() / self.proposals as f64;
        let per_round = self.target_time.as_secs_f64() / self.rounds as f64;
        if per_round > 0.0 {
            per_prop / per_round
        } else {
            f64::NAN
        }
    }

    /// Add another decode's aggregate into this one.
    pub fn merge(&mut self, other: &DecodeStats) {
        self.rounds += other.rounds;
        self.draft_calls += other.draft_calls;
        self.target_calls += other.target_calls;
        self.residual_draws += other.residual_draws;
        self.proposals += other.proposals;
        self.accepted += other.accepted;
        self.sum_alpha += other.sum_alpha;
        self.alpha_count += other.alpha_count;
        self.sum_block_len += other.sum_block_len;
        self.draft_time += other.draft_time;
        self.target_time += other.target_time;
        self.draft_updates += other.draft_updates;
        self.branches_verified += other.branches_verified;
    }
}

/// Result of one decode call.
#[derive(Clone, Debug)]
pub struct DecodeOutput {
    /// Flat `[horizon_patches * patch]` forecast values.
    pub patches: Vec<f32>,
    /// Per-round outcomes in execution order (`gamma` per round is the
    /// replay schedule for `sd_generate_scheduled`).
    pub rounds: Vec<RoundStats>,
    /// Aggregate statistics over all rounds.
    pub stats: DecodeStats,
}

#[cfg(test)]
mod tests {
    use super::*;

    fn round(gamma: usize, accepted: usize, alphas: Vec<f64>) -> RoundStats {
        RoundStats {
            gamma,
            accepted,
            emitted: accepted + 1,
            alphas,
            residual_draws: 0,
            branches: 1,
            draft_time: Duration::from_micros(10),
            target_time: Duration::from_micros(40),
        }
    }

    #[test]
    fn aggregates() {
        let mut s = DecodeStats::default();
        s.absorb(&round(3, 3, vec![1.0, 1.0, 1.0]));
        s.absorb(&round(3, 1, vec![1.0, 0.2]));
        assert_eq!(s.rounds, 2);
        assert_eq!(s.proposals, 6);
        assert_eq!(s.accepted, 4);
        assert!((s.alpha_hat() - 4.2 / 5.0).abs() < 1e-12);
        assert!((s.mean_block_len() - 3.0).abs() < 1e-12);
        assert!((s.accept_rate() - 4.0 / 6.0).abs() < 1e-12);
    }

    #[test]
    fn merge_is_additive() {
        let mut a = DecodeStats::default();
        a.absorb(&round(2, 2, vec![1.0, 1.0]));
        let mut b = DecodeStats::default();
        b.absorb(&round(2, 0, vec![0.1]));
        let mut m = a.clone();
        m.merge(&b);
        assert_eq!(m.rounds, 2);
        assert_eq!(m.accepted, 2);
        assert_eq!(m.alpha_count, 3);
    }

    #[test]
    fn empty_stats_are_nan() {
        let s = DecodeStats::default();
        assert!(s.alpha_hat().is_nan());
        assert!(s.mean_block_len().is_nan());
        assert!(s.cost_ratio().is_nan());
    }

    #[test]
    fn cost_ratio_per_proposal_vs_per_round() {
        let mut s = DecodeStats::default();
        // Two rounds of gamma 2: draft 10us total per round (5us per
        // proposal), target 40us per round => c = 5/40 = 0.125.
        s.absorb(&round(2, 2, vec![1.0, 1.0]));
        s.absorb(&round(2, 1, vec![1.0, 0.2]));
        assert!((s.cost_ratio() - 0.125).abs() < 1e-12, "c {}", s.cost_ratio());
        // A zero-cost draft measures c = 0, not NaN.
        let mut z = DecodeStats::default();
        let mut r = round(2, 2, vec![1.0, 1.0]);
        r.draft_time = Duration::ZERO;
        z.absorb(&r);
        assert_eq!(z.cost_ratio(), 0.0);
    }

    #[test]
    fn tree_rounds_multiply_proposal_accounting() {
        let mut s = DecodeStats::default();
        let mut r = round(3, 2, vec![1.0, 1.0, 0.3, 0.9, 0.1]);
        r.branches = 4;
        s.absorb(&r);
        assert_eq!(s.proposals, 12, "gamma * k proposals drafted");
        assert_eq!(s.draft_calls, 12);
        assert_eq!(s.branches_verified, 4);
        assert_eq!(s.target_calls, 4, "one verify extend per branch");
        // Classic rounds keep branches_verified == rounds.
        s.absorb(&round(3, 3, vec![1.0; 3]));
        assert_eq!(s.branches_verified, 5);
        assert_eq!(s.rounds, 2);
    }

    #[test]
    fn draft_updates_merge_additively() {
        let mut a = DecodeStats::default();
        a.draft_updates = 3;
        let mut b = DecodeStats::default();
        b.draft_updates = 4;
        a.merge(&b);
        assert_eq!(a.draft_updates, 7);
    }
}
