//! Speculative decoding engine (paper §3, Algorithms 1 & 2).
//!
//! A draft backend autoregressively proposes γ patches; the target backend
//! validates all γ+1 prefix conditionals in **one** forward over the
//! extended sequence (causality gives every prefix's next-patch mean in a
//! single pass — the paper's "single batched target pass"). Acceptance is
//! the log-domain rule of Eq. 7 with optional tolerance/bias λ.
//!
//! Two variants:
//! * [`Variant::Practical`] — Algorithm 1: on rejection, fall back to one
//!   draw from p. Output law g = αq + (1-ᾱ)p, TV(g, p) <= ᾱ (Cor. 1).
//! * [`Variant::Lossless`] — Algorithm 2: on rejection, draw from the
//!   residual r ∝ (p - q)_+ via thinning from p (§A.5.1); exact law p
//!   (Theorems 1–2) at expected cost 1/(1-β) target draws per rejection.

//! A third axis (this PR): the *adaptive speculation controller*
//! ([`controller`]) closes the loop between the measured acceptance
//! telemetry and the closed-form speedup curve — per-stream γ (and
//! optionally σ) retuned online, with hysteresis, never changing what is
//! emitted (replay-pinned; see [`sd_generate_scheduled`]).

mod batched;
mod controller;
mod engine;
mod stats;

pub use batched::{sd_generate_batch, sd_generate_stream};
pub use controller::{AdaptiveConfig, ControllerState, GammaController};
pub use engine::{
    sd_generate, sd_generate_scheduled, sd_generate_with_controller, Emission, SpecConfig,
    Variant,
};
pub use stats::{DecodeOutput, DecodeStats, RoundStats};
