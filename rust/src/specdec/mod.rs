//! Speculative decoding engine (paper §3, Algorithms 1 & 2).
//!
//! A draft source autoregressively proposes γ patches; the target backend
//! validates all γ+1 prefix conditionals in **one** forward over the
//! extended sequence (causality gives every prefix's next-patch mean in a
//! single pass — the paper's "single batched target pass"). Acceptance is
//! the log-domain rule of Eq. 7 with optional tolerance/bias λ.
//!
//! Two variants:
//! * [`Variant::Practical`] — Algorithm 1: on rejection, fall back to one
//!   draw from p. Output law g = αq + (1-ᾱ)p, TV(g, p) <= ᾱ (Cor. 1).
//! * [`Variant::Lossless`] — Algorithm 2: on rejection, draw from the
//!   residual r ∝ (p - q)_+ via thinning from p (§A.5.1); exact law p
//!   (Theorems 1–2) at expected cost 1/(1-β) target draws per rejection.

//! A third axis (adaptive-controller PR): the *adaptive speculation
//! controller* ([`GammaController`]) closes the loop between the measured
//! acceptance telemetry and the closed-form speedup curve — per-stream γ
//! (and optionally σ) retuned online, with hysteresis, never changing
//! what is emitted (replay-pinned; see [`sd_generate_scheduled`]).
//!
//! A fourth axis (this PR): *pluggable draft sources* ([`draft`]) — where
//! proposals come from is a trait, not a hard-wired second model. The
//! classic [`ModelDraft`] stays bit-identical to the pre-refactor engine;
//! [`ExtrapolationDraft`] drafts for free from a closed-form continuation
//! (c → 0, the Eq. 5 best case); [`AdaptiveResidualDraft`] learns from
//! each round's verification feedback, pushing the acceptance rate α up
//! online — the controller tunes γ *to* α, the draft source tunes α
//! itself.
//!
//! A fifth axis (serving-scheduler PR): *batching-invariant decodes* —
//! [`sd_generate_stream_seeded`] runs a lockstep batch with per-task
//! seeds and per-sequence γ bucketing, making each sequence's decode
//! bit-identical to its solo [`sd_generate_from`] run regardless of
//! batch composition. This is what lets the serving scheduler promise
//! replica-count- and arrival-order-independent responses.
//!
//! A sixth axis (tree-speculation PR): *multi-candidate drafting* —
//! [`SpecConfig::k`] > 1 drafts k candidate continuations per round
//! ([`draft::DraftSource::propose_k`]), verifies every branch against the
//! shared committed prefix by per-branch extend + rollback of one target
//! session, and commits the longest accepted branch (the `tree` module,
//! capped at [`MAX_TREE_K`]). The k = 1 tree path is bit-identical to the
//! classic engine (`tests/tree_equivalence.rs` — the equivalence wall),
//! and the adaptive controller can retune (γ × k) jointly via
//! [`AdaptiveConfig::k_max`]. Lossless decoding stays restricted to
//! configurations provably identical to k = 1.

mod batched;
mod controller;
pub mod draft;
mod engine;
mod observer;
mod stats;
mod tree;

pub use batched::{
    sd_generate_batch, sd_generate_stream, sd_generate_stream_from, sd_generate_stream_seeded,
};
pub use controller::{AdaptiveConfig, BreakerState, ControllerState, GammaController};
pub use draft::{
    make_batch_source, make_free_source, make_source, AdaptiveResidualDraft, BatchDraftSource,
    DraftConfig, DraftKind, DraftSource, ExtrapolationDraft, ModelBatchDraft, ModelDraft,
    ProposalBlock, RoundFeedback,
};
pub(crate) use engine::ensure_finite;
pub use observer::{with_round_observer, RoundObserver};
pub use engine::{
    sd_generate, sd_generate_from, sd_generate_from_with_controller, sd_generate_scheduled,
    sd_generate_with_controller, Emission, SpecConfig, Variant,
};
pub use stats::{DecodeOutput, DecodeStats, RoundStats};
pub use tree::{
    sd_generate_tree, sd_generate_tree_from, set_stacked_verify, stacked_verify_enabled,
    MAX_TREE_K,
};
