//! Paper-reproduction harness: the shared row driver behind every
//! `cargo bench` target (Tables 1-5, Figures 4-7). Each row evaluates one
//! (dataset, sigma, bias, gamma, pred-len, batch) configuration and reports
//! exactly the columns the paper's tables print.

use anyhow::{Context, Result};

use crate::accept::AcceptancePolicy;
use crate::data::{eval_windows_balanced, Dataset, Window};
use crate::forecast::ar_decode_batch;
use crate::models::{Backend, NativeBackend, XlaBackend};
use crate::runtime::{Engine, Manifest};
use crate::specdec::{sd_generate_batch, sd_generate_stream, DecodeStats, SpecConfig, Variant};
use crate::theory;
use crate::util::tensor::mse_mae;

/// One experiment row configuration.
#[derive(Clone, Debug)]
pub struct RowCfg {
    /// Dataset name (see `data::specs`).
    pub dataset: &'static str,
    /// Acceptance width σ.
    pub sigma: f64,
    /// Acceptance bias λ (1.0 = canonical).
    pub bias: f64,
    /// Draft block length γ.
    pub gamma: usize,
    /// Forecast horizon in patches (4 -> pred-len 96, 14 -> 336).
    pub horizon: usize,
    /// Decode batch size (the paper's batch column).
    pub batch: usize,
    /// Eval windows to average over.
    pub windows: usize,
    /// Run the lossless variant instead of practical.
    pub lossless: bool,
}

impl Default for RowCfg {
    fn default() -> Self {
        RowCfg {
            dataset: "etth1",
            sigma: 0.5,
            bias: 1.0,
            gamma: 3,
            horizon: 4,
            batch: 1,
            windows: default_windows(),
            lossless: false,
        }
    }
}

/// Honor STRIDE_BENCH_QUICK for CI-scale runs.
pub fn default_windows() -> usize {
    if quick() {
        8
    } else {
        28
    }
}

/// Whether `STRIDE_BENCH_QUICK=1` (CI-scale bench trims) is set.
pub fn quick() -> bool {
    std::env::var("STRIDE_BENCH_QUICK").as_deref() == Ok("1")
}

/// One measured row: the paper's Table 1 columns.
#[derive(Clone, Debug)]
pub struct RowResult {
    /// The configuration this row measured.
    pub cfg: RowCfg,
    /// Baseline (target-only AR) mean squared error.
    pub baseline_mse: f64,
    /// Baseline mean absolute error.
    pub baseline_mae: f64,
    /// Speculative-decode mean squared error.
    pub mse: f64,
    /// Speculative-decode mean absolute error.
    pub mae: f64,
    /// Measured mean acceptance probability α̂.
    pub alpha_hat: f64,
    /// Measured mean block length E\[L\].
    pub mean_block_len: f64,
    /// Per-call wall-clock cost ratio measured inside this row's decodes.
    pub c: f64,
    /// Predicted wall-clock speedup (Eq. 5 at the measured α̂/c).
    pub s_wall_pred: f64,
    /// Measured wall-clock speedup (baseline wall / SD wall).
    pub s_wall_meas: f64,
    /// OpsFactor from FLOPs ratio.
    pub ops_factor: f64,
    /// Aggregated decode statistics across the row's windows.
    pub stats: DecodeStats,
}

/// Backends bundle for the harness.
pub struct Bench {
    /// The large target model.
    pub target: Box<dyn Backend>,
    /// The small draft model.
    pub draft: Box<dyn Backend>,
    /// The artifact manifest both were loaded from.
    pub manifest: Manifest,
}

impl Bench {
    /// Paper-protocol path: XLA artifacts (fused kernel), pinned to the
    /// full-context executables — one fixed graph per model, like the
    /// paper's measurement setup, so c is constant across context lengths.
    /// (Production serving uses shape routing instead; see `xla_routed`.)
    pub fn xla() -> Result<Bench> {
        let manifest = Manifest::load(&crate::artifacts_dir())
            .context("artifacts required: run `make artifacts`")?;
        let mut engine = Engine::cpu()?;
        let target = XlaBackend::load_filtered(&mut engine, &manifest, "target", "fused", true)?;
        let draft = XlaBackend::load_filtered(&mut engine, &manifest, "draft", "fused", true)?;
        Ok(Bench { target: Box::new(target), draft: Box::new(draft), manifest })
    }

    /// Production path with sequence-length shape routing (the §Perf
    /// optimization): short contexts hit cheaper executables, improving
    /// absolute latency for *both* AR and SD (and narrowing SD's relative
    /// gain at short contexts — see EXPERIMENTS.md §Perf).
    pub fn xla_routed() -> Result<Bench> {
        let manifest = Manifest::load(&crate::artifacts_dir())
            .context("artifacts required: run `make artifacts`")?;
        let mut engine = Engine::cpu()?;
        let target = XlaBackend::load(&mut engine, &manifest, "target", "fused")?;
        let draft = XlaBackend::load(&mut engine, &manifest, "draft", "fused")?;
        Ok(Bench { target: Box::new(target), draft: Box::new(draft), manifest })
    }

    /// PJRT-free path for fast ablations.
    pub fn native() -> Result<Bench> {
        let manifest = Manifest::load(&crate::artifacts_dir())
            .context("artifacts required: run `make artifacts`")?;
        let (t, d) = NativeBackend::pair_from_manifest(&manifest)?;
        Ok(Bench { target: Box::new(t), draft: Box::new(d), manifest })
    }

    /// From env: STRIDE_BENCH_BACKEND=native|xla (default xla).
    pub fn from_env() -> Result<Bench> {
        match std::env::var("STRIDE_BENCH_BACKEND").as_deref() {
            Ok("native") => Bench::native(),
            Ok("xla-routed") => Bench::xla_routed(),
            _ => Bench::xla(),
        }
    }

    /// Cut the balanced eval windows a row configuration asks for.
    pub fn windows(&self, cfg: &RowCfg) -> Result<Vec<Window>> {
        let data = Dataset::by_name(cfg.dataset)
            .with_context(|| format!("unknown dataset {}", cfg.dataset))?;
        let stride = cfg.horizon * self.manifest.patch;
        Ok(eval_windows_balanced(&data, self.manifest.patch, 4, cfg.horizon, stride, cfg.windows))
    }

    /// Run one row: batched baseline AR + batched SD over the same windows.
    pub fn run_row(&self, cfg: &RowCfg) -> Result<RowResult> {
        let p = self.manifest.patch;
        let windows = self.windows(cfg)?;
        anyhow::ensure!(!windows.is_empty(), "no eval windows");

        let spec = SpecConfig {
            gamma: cfg.gamma,
            k: 1,
            policy: AcceptancePolicy::new(cfg.sigma, cfg.bias),
            variant: if cfg.lossless { Variant::Lossless } else { Variant::Practical },
            seed: 0x57121DE,
            max_residual_draws: 10_000,
            emission: if cfg.lossless {
                crate::specdec::Emission::Sampled
            } else {
                crate::specdec::Emission::Mean
            },
            cache: crate::models::CacheMode::On,
            draft: crate::specdec::DraftConfig::default(),
            adaptive: None,
        };

        // Warmup: one untimed baseline + SD pass so first-row results don't
        // absorb lazy PJRT initialization cost.
        {
            let w = &windows[0];
            let tasks: Vec<(&[f32], usize, usize)> =
                vec![(w.history.as_slice(), w.history.len() / p, cfg.horizon)];
            let _ = ar_decode_batch(self.target.as_ref(), &tasks)?;
            let _ = sd_generate_batch(self.target.as_ref(), self.draft.as_ref(), &tasks, &spec)?;
        }

        let mut baseline_se = 0.0;
        let mut baseline_ae = 0.0;
        let mut baseline_wall = std::time::Duration::ZERO;
        let mut sd_se = 0.0;
        let mut sd_ae = 0.0;
        let mut sd_wall = std::time::Duration::ZERO;
        let mut stats = DecodeStats::default();

        // Baseline: batched greedy target AR in fixed chunks (equal horizons,
        // zero scheduling waste — the strongest fair baseline).
        for chunk in windows.chunks(cfg.batch) {
            let tasks: Vec<(&[f32], usize, usize)> = chunk
                .iter()
                .map(|w| (w.history.as_slice(), w.history.len() / p, cfg.horizon))
                .collect();
            let (preds, wall) = ar_decode_batch(self.target.as_ref(), &tasks)?;
            baseline_wall += wall;
            for (pred, w) in preds.iter().zip(chunk) {
                let (se, ae) = mse_mae(pred, &w.future);
                baseline_se += se;
                baseline_ae += ae;
            }
        }
        // Speculative decode: continuous batching over all windows with at
        // most `cfg.batch` active sequences (per-sequence seeds are derived
        // inside the engine, so coins are independent across windows).
        {
            let tasks: Vec<(&[f32], usize, usize)> = windows
                .iter()
                .map(|w| (w.history.as_slice(), w.history.len() / p, cfg.horizon))
                .collect();
            let t0 = std::time::Instant::now();
            let outs = sd_generate_stream(
                self.target.as_ref(),
                self.draft.as_ref(),
                &tasks,
                cfg.batch,
                &spec,
            )?;
            sd_wall += t0.elapsed();
            for (out, w) in outs.iter().zip(&windows) {
                let (se, ae) = mse_mae(&out.patches, &w.future);
                sd_se += se;
                sd_ae += ae;
                stats.merge(&out.stats);
            }
        }

        let n = windows.len() as f64;
        let alpha_hat = stats.alpha_hat();
        // Measured per-call cost ratio c from this row's own decode timers.
        let draft_per_call = stats.draft_time.as_secs_f64() / stats.draft_calls.max(1) as f64;
        let target_fwd_calls = stats.rounds.max(1);
        let target_per_call = stats.target_time.as_secs_f64() / target_fwd_calls as f64;
        let c = draft_per_call / target_per_call;
        let c_hat = self.draft.flops(self.manifest.n_ctx) / self.target.flops(self.manifest.n_ctx);

        Ok(RowResult {
            cfg: cfg.clone(),
            baseline_mse: baseline_se / n,
            baseline_mae: baseline_ae / n,
            mse: sd_se / n,
            mae: sd_ae / n,
            alpha_hat,
            mean_block_len: stats.mean_block_len(),
            c,
            s_wall_pred: theory::wall_speedup(alpha_hat.min(1.0), cfg.gamma, c),
            s_wall_meas: baseline_wall.as_secs_f64() / sd_wall.as_secs_f64(),
            ops_factor: theory::ops_factor(alpha_hat.min(1.0), cfg.gamma, c_hat),
            stats,
        })
    }
}

/// Format one Table-1-style row.
pub fn fmt_row(r: &RowResult) -> Vec<String> {
    vec![
        r.cfg.dataset.to_string(),
        format!(
            "0.25x draft (s={}, b={}, g={}, pred={}{})",
            r.cfg.sigma,
            r.cfg.batch,
            r.cfg.gamma,
            r.cfg.horizon * 24,
            if r.cfg.bias != 1.0 { format!(", bias={}", r.cfg.bias) } else { String::new() }
        ),
        format!("{:.4}", r.mse),
        format!("{:.4}", r.mae),
        format!("{:.3}", r.alpha_hat),
        format!("{:.2}", r.mean_block_len),
        format!("{}", r.cfg.gamma),
        format!("{:.3}", r.c),
        format!("{:.2}x / {:.2}x", r.s_wall_pred, r.s_wall_meas),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn harness_runs_on_native_backend() {
        if !crate::artifacts_dir().join("manifest.json").exists() {
            eprintln!("SKIP: run `make artifacts`");
            return;
        }
        let bench = Bench::native().unwrap();
        let cfg = RowCfg { windows: 4, batch: 2, ..Default::default() };
        let r = bench.run_row(&cfg).unwrap();
        assert!(r.mse.is_finite() && r.mse > 0.0);
        assert!(r.baseline_mse.is_finite());
        assert!(r.alpha_hat > 0.0 && r.alpha_hat <= 1.0 + 1e-9);
        assert!(r.mean_block_len >= 1.0 && r.mean_block_len <= (cfg.gamma + 1) as f64 + 1e-9);
        assert!(r.s_wall_meas > 0.0);
        assert!(r.c > 0.0 && r.c < 1.5, "draft should be cheaper: c={}", r.c);
    }
}
