//! STRIDE CLI — the leader entrypoint.
//!
//! Subcommands:
//!   serve     start the forecasting service (the paper's deployment mode)
//!   eval      offline accuracy/speed eval of one configuration
//!   plan      estimate alpha-hat + pick gamma* from held-out windows
//!   info      print artifact/manifest information
//!
//! Run `stride <cmd> --help` conventions: all flags are `--key value`;
//! see `config::ServeConfig` for the full list.

use anyhow::{bail, Context, Result};

use stride::accept::AcceptancePolicy;
use stride::config::{Cli, ServeConfig};
use stride::data::{eval_windows, Dataset};
use stride::forecast::{eval_ar, eval_sd};
use stride::models::{Backend, NativeBackend, XlaBackend};
use stride::runtime::{Engine, Manifest};
use stride::specdec::SpecConfig;
use stride::theory;

const USAGE: &str = "\
stride <command> [--key value ...]

commands:
  serve   start the HTTP forecasting service
          --bind 127.0.0.1:8080 --backend xla|native --kernel fused|pallas
          --gamma 3 --sigma 0.5 --bias 1.0 --max-batch 8 --max-wait-ms 2
          --replicas N (engine replica pool; native backend only for N>1)
          --queue-cap N (bounded admission; 429 + Retry-After when full)
          --sched edf|fifo (priority + earliest-deadline-first dispatch,
          or arrival order) --default-deadline-ms N (0 = none)
          --retry-after-ms N (shed back-off hint)
          --draft model|extrap|adaptive (proposal source: second model,
          draft-free extrapolation, or online-learned head)
          --draft-period N (extrap: seasonal period in patches; 0=linear)
          --draft-eta X (adaptive: NLMS rate in (0,2)); also via config
          \"draft\": {...} and per-request \"draft\" override
          --adaptive (online gamma controller; knobs via config
          \"adaptive\": {...}) --lossless --greedy --baseline --no-cache
          --threads N (native kernel pool; 0 = auto/STRIDE_THREADS)
  eval    offline eval: --dataset etth1 --horizon 4 --windows 28
          [--gamma/--sigma/--no-cache...]
  plan    acceptance estimation + gamma scan: --dataset etth1 --windows 64
  info    print the artifacts manifest summary
";

fn main() {
    env_logger_lite();
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

/// Minimal env_logger replacement: honor RUST_LOG=info|debug via the `log`
/// crate's max level (messages go to stderr).
fn env_logger_lite() {
    struct L;
    impl log::Log for L {
        fn enabled(&self, _: &log::Metadata) -> bool {
            true
        }
        fn log(&self, record: &log::Record) {
            eprintln!("[{}] {}", record.level(), record.args());
        }
        fn flush(&self) {}
    }
    static LOGGER: L = L;
    let level = match std::env::var("RUST_LOG").as_deref() {
        Ok("debug") => log::LevelFilter::Debug,
        Ok("trace") => log::LevelFilter::Trace,
        Ok("warn") => log::LevelFilter::Warn,
        Ok("error") => log::LevelFilter::Error,
        _ => log::LevelFilter::Info,
    };
    let _ = log::set_logger(&LOGGER).map(|_| log::set_max_level(level));
}

fn run() -> Result<()> {
    let cli = Cli::from_env()?;
    let cmd = cli.positional.first().map(String::as_str).unwrap_or("help");
    match cmd {
        "serve" => cmd_serve(&cli),
        "eval" => cmd_eval(&cli),
        "plan" => cmd_plan(&cli),
        "info" => cmd_info(&cli),
        "help" | "--help" | "-h" => {
            println!("{USAGE}");
            Ok(())
        }
        other => bail!("unknown command '{other}'\n{USAGE}"),
    }
}

fn cmd_serve(cli: &Cli) -> Result<()> {
    let mut cfg = ServeConfig::default();
    cfg.apply_cli(cli)?;
    let server = stride::server::Server::start(cfg)?;
    println!("stride serving on http://{}  (Ctrl-C to stop)", server.addr());
    // Block forever; the OS reclaims everything on SIGINT.
    loop {
        std::thread::sleep(std::time::Duration::from_secs(3600));
    }
}

fn load_backends(cli: &Cli) -> Result<(Box<dyn Backend>, Box<dyn Backend>, Manifest)> {
    let mut cfg = ServeConfig::default();
    cfg.apply_cli(cli)?;
    let manifest = Manifest::load(&cfg.artifacts)?;
    match cfg.backend.as_str() {
        "native" => {
            let (t, d) = NativeBackend::pair_from_manifest(&manifest)?;
            Ok((Box::new(t), Box::new(d), manifest))
        }
        _ => {
            let mut engine = Engine::cpu()?;
            let t = XlaBackend::load(&mut engine, &manifest, "target", &cfg.kernel)?;
            let d = XlaBackend::load(&mut engine, &manifest, "draft", &cfg.kernel)?;
            Ok((Box::new(t), Box::new(d), manifest))
        }
    }
}

fn cmd_eval(cli: &Cli) -> Result<()> {
    let dataset = cli.get("dataset").unwrap_or("etth1");
    let horizon = cli.get_usize("horizon")?.unwrap_or(4);
    let n_windows = cli.get_usize("windows")?.unwrap_or(28);
    let gamma = cli.get_usize("gamma")?.unwrap_or(3);
    let sigma = cli.get_f64("sigma")?.unwrap_or(0.5);
    let bias = cli.get_f64("bias")?.unwrap_or(1.0);

    let (target, draft, manifest) = load_backends(cli)?;
    let data = Dataset::by_name(dataset).with_context(|| format!("unknown dataset {dataset}"))?;
    let windows =
        eval_windows(&data, manifest.patch, 4, horizon, horizon * manifest.patch, n_windows);
    println!(
        "eval: dataset={dataset} windows={} horizon={horizon} patches gamma={gamma} sigma={sigma}",
        windows.len()
    );

    let base = eval_ar(target.as_ref(), &windows, manifest.patch)?;
    println!(
        "baseline (target AR): MSE {:.4}  MAE {:.4}  wall {:.2}s  {:.1} patches/s",
        base.mse,
        base.mae,
        base.wall.as_secs_f64(),
        base.throughput_patches_per_s()
    );

    let mut spec = SpecConfig::default();
    spec.gamma = gamma;
    spec.policy = AcceptancePolicy::new(sigma, bias);
    if cli.flag("no-cache") {
        spec.cache = stride::models::CacheMode::Off;
    }
    let sd = eval_sd(target.as_ref(), draft.as_ref(), &windows, manifest.patch, &spec)?;
    let speedup = base.wall.as_secs_f64() / sd.wall.as_secs_f64();
    println!(
        "speculative:          MSE {:.4}  MAE {:.4}  wall {:.2}s  {:.1} patches/s  S_wall {:.2}x",
        sd.mse,
        sd.mae,
        sd.wall.as_secs_f64(),
        sd.throughput_patches_per_s(),
        speedup
    );
    println!(
        "acceptance: alpha_hat {:.4}  E[L] {:.2}  rounds {}  draft_calls {}  target_calls {}",
        sd.sd.alpha_hat(),
        sd.sd.mean_block_len(),
        sd.sd.rounds,
        sd.sd.draft_calls,
        sd.sd.target_calls
    );
    Ok(())
}

fn cmd_plan(cli: &Cli) -> Result<()> {
    let dataset = cli.get("dataset").unwrap_or("etth1");
    let n_windows = cli.get_usize("windows")?.unwrap_or(64);
    let sigma = cli.get_f64("sigma")?.unwrap_or(0.5);

    let (target, draft, manifest) = load_backends(cli)?;
    let data = Dataset::by_name(dataset).with_context(|| format!("unknown dataset {dataset}"))?;
    let windows = eval_windows(&data, manifest.patch, 4, 1, 24, n_windows);
    let policy = AcceptancePolicy::new(sigma, 1.0);

    // Closed-form alpha-hat over held-out histories (Prop. 4 / Remark 5).
    let p = manifest.patch;
    let mut heads: Vec<(Vec<f32>, Vec<f32>)> = Vec::new();
    for w in &windows {
        let n = w.history.len() / p;
        let mp = target.forward(&w.history, n)?;
        let md = draft.forward(&w.history, n)?;
        heads.push((mp[(n - 1) * p..n * p].to_vec(), md[(n - 1) * p..n * p].to_vec()));
    }
    let est = stride::accept::estimate_alpha_closed_form(
        &policy,
        heads.iter().map(|(a, b)| (a.as_slice(), b.as_slice())),
    );
    // Measured cost ratio from the forwards above.
    let c = draft.mean_secs() / target.mean_secs();
    let c_hat = draft.flops(manifest.n_ctx) / target.flops(manifest.n_ctx);
    println!(
        "alpha_hat = {:.4} +- {:.4} (95% Hoeffding, N={})   c = {:.3}   c_hat = {:.3}",
        est.alpha_hat, est.eps95, est.n_histories, c, c_hat
    );
    let g_star = theory::optimal_gamma(est.alpha_hat, c, 16);
    println!("gamma* (Prop. 3) = {g_star}");
    println!("\n gamma   E[L]    S_wall   OpsFactor");
    for gamma in [1usize, 2, 3, 4, 5, 7, 10] {
        let pr = theory::predict(est.alpha_hat, gamma, c, c_hat);
        println!(
            "  {gamma:>3}   {:>5.2}   {:>6.2}x   {:>7.2}{}",
            pr.expected_l,
            pr.s_wall,
            pr.ops_factor,
            if gamma == g_star { "   <- gamma*" } else { "" }
        );
    }
    Ok(())
}

fn cmd_info(cli: &Cli) -> Result<()> {
    let mut cfg = ServeConfig::default();
    cfg.apply_cli(cli)?;
    let m = Manifest::load(&cfg.artifacts)?;
    println!("artifacts: {}", m.dir.display());
    println!("patch={} n_ctx={} batches={:?} quick={}", m.patch, m.n_ctx, m.batches, m.quick);
    println!(
        "target: {} ({} params, d_model={} layers={})",
        m.target.name, m.target.param_count, m.target.dims.d_model, m.target.dims.n_layers
    );
    println!(
        "draft:  {} ({} params, d_model={} layers={}, {:.1}% of target)",
        m.draft.name,
        m.draft.param_count,
        m.draft.dims.d_model,
        m.draft.dims.n_layers,
        100.0 * m.draft.param_count as f64 / m.target.param_count as f64
    );
    println!("distill: sigma={} mean_gap={:.4}", m.distill_sigma, m.mean_gap);
    println!("{} HLO artifacts:", m.artifacts.len());
    for a in &m.artifacts {
        println!("  {} (model={} batch={} kernel={})", a.file.file_name().unwrap().to_string_lossy(), a.model, a.batch, a.kernel);
    }
    Ok(())
}
