//! Serving metrics registry (thread-safe): request counters, latency
//! histograms, acceptance monitoring. Exposed at `/metrics` in a
//! Prometheus-style text format and consumed by the adaptive-γ controller.
//!
//! The paper's §7 deployment guidance — "comprehensive monitoring of
//! acceptance rates ᾱ across traffic segments, adaptive thresholds during
//! anomalous periods" — is implemented by [`AcceptanceMonitor`].

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Duration;

use crate::util::stats::LatencyHistogram;

/// Thread-safe metrics registry: named counters, float gauges, and
/// latency histograms, rendered at `/metrics`.
#[derive(Default)]
pub struct Metrics {
    counters: Mutex<BTreeMap<String, u64>>,
    gauges: Mutex<BTreeMap<String, f64>>,
    histograms: Mutex<BTreeMap<String, LatencyHistogram>>,
    /// Requests admitted by the batcher (all modes).
    pub requests_total: AtomicU64,
    /// Forecast patches emitted across all requests.
    pub patches_total: AtomicU64,
    /// Requests that failed validation or decoding.
    pub errors_total: AtomicU64,
    /// Requests shed by the bounded admission queue (tail-dropped at the
    /// cap or evicted for a higher-priority arrival) — HTTP 429s.
    pub sheds_total: AtomicU64,
    /// Requests whose deadline expired while queued (failed fast,
    /// never decoded) — HTTP 504s.
    pub expired_total: AtomicU64,
}

impl Metrics {
    /// Fresh, empty registry.
    pub fn new() -> Metrics {
        Metrics::default()
    }

    /// Add `by` to the named counter (created at 0 on first use).
    pub fn inc(&self, name: &str, by: u64) {
        *self.counters.lock().unwrap().entry(name.to_string()).or_insert(0) += by;
    }

    /// Current value of a named counter (0 when never incremented).
    pub fn counter(&self, name: &str) -> u64 {
        self.counters.lock().unwrap().get(name).copied().unwrap_or(0)
    }

    /// Set a named gauge to an instantaneous value (last write wins —
    /// e.g. the adaptive controller's current γ / α̂ / c snapshot).
    /// Non-finite values clear the gauge instead of rendering as NaN.
    pub fn set_gauge(&self, name: &str, v: f64) {
        let mut g = self.gauges.lock().unwrap();
        if v.is_finite() {
            g.insert(name.to_string(), v);
        } else {
            g.remove(name);
        }
    }

    /// Current value of a named gauge, if set.
    pub fn gauge(&self, name: &str) -> Option<f64> {
        self.gauges.lock().unwrap().get(name).copied()
    }

    /// Fold an observation into a named gauge as an EWMA with decay
    /// `lam` in [0, 1) (first observation seeds the gauge). Non-finite
    /// observations are dropped. Used for per-draft-source α̂ and cost
    /// gauges, where a last-write-wins gauge would just echo the most
    /// recent decode group's noise.
    pub fn ewma_gauge(&self, name: &str, v: f64, lam: f64) {
        if !v.is_finite() {
            return;
        }
        let mut g = self.gauges.lock().unwrap();
        let e = g.entry(name.to_string()).or_insert(v);
        *e = lam * *e + (1.0 - lam) * v;
    }

    /// Record one request's deadline outcome into the overall and
    /// per-priority SLO counters and refresh the per-priority
    /// attainment gauge (`slo_attainment_<prio>` = met / (met+missed)).
    /// Served requests report met/missed by latency; **shed and expired
    /// requests count as missed** — the SLO is about what the client
    /// experienced, not about what happened to decode.
    ///
    /// The increments and the per-priority reads happen under **one**
    /// counters-lock acquisition: taking the lock per operation would
    /// let a concurrent outcome interleave between this outcome's
    /// increment and its read, publishing an attainment computed from
    /// torn counts (and, worse, letting the *stale* computation win the
    /// gauge race after the fresher one).
    pub fn record_deadline_outcome(&self, prio: &str, met: bool) {
        let which = if met { "met" } else { "missed" };
        let mut c = self.counters.lock().unwrap();
        *c.entry(if met { "deadline_met" } else { "deadline_missed" }.to_string())
            .or_insert(0) += 1;
        *c.entry(format!("deadline_{which}_{prio}")).or_insert(0) += 1;
        let met_n = c.get(&format!("deadline_met_{prio}")).copied().unwrap_or(0);
        let miss_n = c.get(&format!("deadline_missed_{prio}")).copied().unwrap_or(0);
        // Publish under the counters lock (counters → gauges is the only
        // nested order anywhere; render() takes them sequentially), so
        // the gauge always reflects the latest consistent snapshot.
        if met_n + miss_n > 0 {
            self.set_gauge(
                &format!("slo_attainment_{prio}"),
                met_n as f64 / (met_n + miss_n) as f64,
            );
        }
    }

    /// Record one duration into the named latency histogram.
    pub fn observe(&self, name: &str, d: Duration) {
        self.histograms
            .lock()
            .unwrap()
            .entry(name.to_string())
            .or_default()
            .record(d);
    }

    /// Quantile of a named latency histogram, in milliseconds (0 when the
    /// histogram does not exist).
    pub fn quantile_ms(&self, name: &str, q: f64) -> f64 {
        self.histograms
            .lock()
            .unwrap()
            .get(name)
            .map(|h| h.quantile_ns(q) / 1e6)
            .unwrap_or(0.0)
    }

    /// Prometheus-style text dump.
    pub fn render(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!(
            "stride_requests_total {}\nstride_patches_total {}\nstride_errors_total {}\nstride_sheds_total {}\nstride_expired_total {}\n",
            self.requests_total.load(Ordering::Relaxed),
            self.patches_total.load(Ordering::Relaxed),
            self.errors_total.load(Ordering::Relaxed),
            self.sheds_total.load(Ordering::Relaxed),
            self.expired_total.load(Ordering::Relaxed),
        ));
        for (k, v) in self.counters.lock().unwrap().iter() {
            out.push_str(&format!("stride_{k} {v}\n"));
        }
        for (k, v) in self.gauges.lock().unwrap().iter() {
            out.push_str(&format!("stride_{k} {v}\n"));
        }
        for (k, h) in self.histograms.lock().unwrap().iter() {
            if h.count() == 0 {
                continue;
            }
            out.push_str(&format!(
                "stride_{k}_count {}\nstride_{k}_mean_ms {:.4}\nstride_{k}_p50_ms {:.4}\nstride_{k}_p95_ms {:.4}\nstride_{k}_p99_ms {:.4}\n",
                h.count(),
                h.mean_ns() / 1e6,
                h.quantile_ns(0.50) / 1e6,
                h.quantile_ns(0.95) / 1e6,
                h.quantile_ns(0.99) / 1e6,
            ));
        }
        out
    }
}

/// Sliding-window acceptance monitor with an adaptive-γ recommendation
/// (paper §7 "golden path" guidance + Prop. 3 online).
pub struct AcceptanceMonitor {
    window: usize,
    inner: Mutex<MonitorState>,
    /// Alert when windowed ᾱ drops below this (distribution shift guard).
    pub alert_threshold: f64,
}

struct MonitorState {
    alphas: std::collections::VecDeque<f64>,
    sum: f64,
    /// Evictions since `sum` was last recomputed from the deque. The
    /// incremental `+=`/`-=` running sum accumulates float error across
    /// millions of records; every [`SUM_REFRESH_EVICTIONS`] evictions the
    /// sum is rebuilt exactly from the live window, bounding drift.
    evictions: usize,
}

/// Evictions between exact running-sum rebuilds in [`AcceptanceMonitor`].
const SUM_REFRESH_EVICTIONS: usize = 1024;

impl AcceptanceMonitor {
    /// Monitor over the last `window` per-request acceptance means,
    /// alerting below `alert_threshold`.
    pub fn new(window: usize, alert_threshold: f64) -> AcceptanceMonitor {
        AcceptanceMonitor {
            window,
            inner: Mutex::new(MonitorState {
                alphas: Default::default(),
                sum: 0.0,
                evictions: 0,
            }),
            alert_threshold,
        }
    }

    /// Record one request's mean acceptance probability.
    pub fn record(&self, alpha: f64) {
        let mut s = self.inner.lock().unwrap();
        s.alphas.push_back(alpha);
        s.sum += alpha;
        if s.alphas.len() > self.window {
            if let Some(old) = s.alphas.pop_front() {
                s.sum -= old;
            }
            s.evictions += 1;
            // Periodic exact rebuild: long-lived windows otherwise drift
            // (catastrophic cancellation in += / -= over millions of
            // records), and alpha_bar feeds γ recommendations.
            if s.evictions >= SUM_REFRESH_EVICTIONS {
                s.evictions = 0;
                s.sum = s.alphas.iter().sum();
            }
        }
    }

    /// Windowed mean acceptance (NaN when empty).
    pub fn alpha_bar(&self) -> f64 {
        let s = self.inner.lock().unwrap();
        if s.alphas.is_empty() {
            f64::NAN
        } else {
            s.sum / s.alphas.len() as f64
        }
    }

    /// Samples currently in the window.
    pub fn n(&self) -> usize {
        self.inner.lock().unwrap().alphas.len()
    }

    /// True when the windowed acceptance indicates distribution shift.
    pub fn degraded(&self) -> bool {
        let a = self.alpha_bar();
        a.is_finite() && a < self.alert_threshold
    }

    /// Recommend γ from the windowed ᾱ and a measured cost ratio c
    /// (Prop. 3), conservatively dropping to 1 when degraded.
    pub fn recommend_gamma(&self, c: f64, cap: usize) -> usize {
        if self.degraded() || self.n() == 0 {
            return 1;
        }
        crate::theory::optimal_gamma(self.alpha_bar(), c, cap)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_and_histograms_render() {
        let m = Metrics::new();
        m.requests_total.fetch_add(3, Ordering::Relaxed);
        m.inc("batches", 2);
        m.observe("latency", Duration::from_millis(5));
        m.observe("latency", Duration::from_millis(15));
        let text = m.render();
        assert!(text.contains("stride_requests_total 3"));
        assert!(text.contains("stride_batches 2"));
        assert!(text.contains("stride_latency_count 2"));
        assert!(m.quantile_ms("latency", 0.5) > 1.0);
    }

    #[test]
    fn scheduler_counters_render() {
        let m = Metrics::new();
        m.sheds_total.fetch_add(4, Ordering::Relaxed);
        m.expired_total.fetch_add(2, Ordering::Relaxed);
        let text = m.render();
        assert!(text.contains("stride_sheds_total 4"));
        assert!(text.contains("stride_expired_total 2"));
    }

    #[test]
    fn deadline_outcomes_drive_slo_gauge() {
        let m = Metrics::new();
        m.record_deadline_outcome("high", true);
        m.record_deadline_outcome("high", true);
        m.record_deadline_outcome("high", false); // e.g. expired in queue
        assert_eq!(m.counter("deadline_met_high"), 2);
        assert_eq!(m.counter("deadline_missed_high"), 1);
        let g = m.gauge("slo_attainment_high").unwrap();
        assert!((g - 2.0 / 3.0).abs() < 1e-12, "attainment {g}");
        // Other bands are independent.
        m.record_deadline_outcome("low", false);
        assert_eq!(m.gauge("slo_attainment_low"), Some(0.0));
        assert!((m.gauge("slo_attainment_high").unwrap() - 2.0 / 3.0).abs() < 1e-12);
    }

    #[test]
    fn gauges_render_and_clear_on_nonfinite() {
        let m = Metrics::new();
        m.set_gauge("controller_gamma", 5.0);
        assert_eq!(m.gauge("controller_gamma"), Some(5.0));
        assert!(m.render().contains("stride_controller_gamma 5"));
        m.set_gauge("controller_gamma", f64::NAN);
        assert_eq!(m.gauge("controller_gamma"), None);
        assert!(!m.render().contains("controller_gamma"), "NaN gauge must not render");
    }

    #[test]
    fn ewma_gauge_folds_and_drops_nonfinite() {
        let m = Metrics::new();
        m.ewma_gauge("draft_model_alpha_hat", 1.0, 0.5);
        assert_eq!(m.gauge("draft_model_alpha_hat"), Some(1.0), "first obs seeds");
        m.ewma_gauge("draft_model_alpha_hat", 0.0, 0.5);
        assert_eq!(m.gauge("draft_model_alpha_hat"), Some(0.5));
        m.ewma_gauge("draft_model_alpha_hat", f64::NAN, 0.5);
        assert_eq!(m.gauge("draft_model_alpha_hat"), Some(0.5), "NaN obs dropped");
        assert!(m.render().contains("stride_draft_model_alpha_hat"));
    }

    #[test]
    fn monitor_windowed_mean() {
        let mon = AcceptanceMonitor::new(4, 0.5);
        for a in [1.0, 1.0, 0.0, 0.0] {
            mon.record(a);
        }
        assert!((mon.alpha_bar() - 0.5).abs() < 1e-12);
        // Window slides: two more 1.0s evict the early 1.0s.
        mon.record(1.0);
        mon.record(1.0);
        assert!((mon.alpha_bar() - 0.5).abs() < 1e-12); // 0,0,1,1
        mon.record(1.0);
        assert!(mon.alpha_bar() > 0.7);
    }

    #[test]
    fn monitor_sum_rebuild_bounds_drift() {
        // A catastrophic-cancellation victim: 1e15 swallows 1e-3 in the
        // running sum, so after the big value is evicted the incremental
        // sum is off by the entire small-value mass. The periodic exact
        // rebuild (every SUM_REFRESH_EVICTIONS evictions) must restore
        // alpha_bar to the true window mean.
        let mon = AcceptanceMonitor::new(2, 0.0);
        mon.record(1e15);
        for _ in 0..(2 * SUM_REFRESH_EVICTIONS) {
            mon.record(1e-3);
        }
        assert!(
            (mon.alpha_bar() - 1e-3).abs() < 1e-15,
            "alpha_bar drifted: {}",
            mon.alpha_bar()
        );
    }

    #[test]
    fn monitor_degradation_and_gamma() {
        let mon = AcceptanceMonitor::new(10, 0.6);
        for _ in 0..10 {
            mon.record(0.3);
        }
        assert!(mon.degraded());
        assert_eq!(mon.recommend_gamma(0.2, 10), 1, "conservative under shift");
        for _ in 0..10 {
            mon.record(0.99);
        }
        assert!(!mon.degraded());
        assert!(mon.recommend_gamma(0.1, 10) > 2);
    }
}
