//! Gaussian patch heads (paper §2, §3.6, Remark 1/5).
//!
//! Both target and draft parameterize the next patch as N(mu(H), sigma^2 I)
//! with a shared per-sample sigma (the paper's swept noise knob). This
//! module provides log-densities, sampling, the closed-form equal-covariance
//! overlap, and the diagonal-covariance extension (Remark 1).

use crate::util::rng::Rng;
use crate::util::stats::phi;

/// Isotropic Gaussian head: mean vector + shared scalar sigma.
#[derive(Clone, Debug, PartialEq)]
pub struct IsoGaussian {
    /// Mean vector (one entry per patch dimension).
    pub mean: Vec<f32>,
    /// Shared scalar standard deviation.
    pub sigma: f64,
}

impl IsoGaussian {
    /// Head with the given mean and (positive) sigma.
    pub fn new(mean: Vec<f32>, sigma: f64) -> Self {
        assert!(sigma > 0.0, "sigma must be positive");
        IsoGaussian { mean, sigma }
    }

    /// Patch dimensionality.
    pub fn dim(&self) -> usize {
        self.mean.len()
    }

    /// log N(x; mean, sigma^2 I).
    pub fn log_density(&self, x: &[f32]) -> f64 {
        assert_eq!(x.len(), self.dim());
        let d = self.dim() as f64;
        let s2 = self.sigma * self.sigma;
        let sq: f64 = x
            .iter()
            .zip(&self.mean)
            .map(|(a, b)| {
                let d = (*a - *b) as f64;
                d * d
            })
            .sum();
        -0.5 * (d * (2.0 * std::f64::consts::PI * s2).ln() + sq / s2)
    }

    /// Draw x ~ N(mean, sigma^2 I).
    pub fn sample(&self, rng: &mut Rng) -> Vec<f32> {
        let mut out = vec![0.0f32; self.dim()];
        rng.fill_normal_around(&self.mean, self.sigma as f32, &mut out);
        out
    }

    /// Squared L2 distance between means.
    pub fn mean_gap_sq(&self, other: &IsoGaussian) -> f64 {
        assert_eq!(self.dim(), other.dim());
        self.mean
            .iter()
            .zip(&other.mean)
            .map(|(a, b)| {
                let d = (*a - *b) as f64;
                d * d
            })
            .sum()
    }

    /// Closed-form overlap beta = ∫ min{p, q} for equal-sigma heads
    /// (paper Remark 5): beta = 2 Phi(-Delta/2), Delta = ||mu_p - mu_q|| / sigma.
    pub fn overlap(&self, other: &IsoGaussian) -> f64 {
        assert!(
            (self.sigma - other.sigma).abs() < 1e-12,
            "closed-form overlap requires equal sigma"
        );
        let delta = self.mean_gap_sq(other).sqrt() / self.sigma;
        2.0 * phi(-delta / 2.0)
    }
}

/// Diagonal-covariance head (paper Remark 1 extension). More expressive —
/// can raise acceptance by matching the target better — at higher per-step
/// evaluation cost; the ablation bench compares both.
#[derive(Clone, Debug, PartialEq)]
pub struct DiagGaussian {
    /// Mean vector (one entry per patch dimension).
    pub mean: Vec<f32>,
    /// Per-dimension standard deviations.
    pub sigmas: Vec<f32>,
}

impl DiagGaussian {
    /// Head with the given mean and (positive) per-dimension sigmas.
    pub fn new(mean: Vec<f32>, sigmas: Vec<f32>) -> Self {
        assert_eq!(mean.len(), sigmas.len());
        assert!(sigmas.iter().all(|s| *s > 0.0));
        DiagGaussian { mean, sigmas }
    }

    /// Patch dimensionality.
    pub fn dim(&self) -> usize {
        self.mean.len()
    }

    /// log N(x; mean, diag(sigmas²)).
    pub fn log_density(&self, x: &[f32]) -> f64 {
        let mut acc = -0.5 * self.dim() as f64 * (2.0 * std::f64::consts::PI).ln();
        for i in 0..self.dim() {
            let s = self.sigmas[i] as f64;
            let d = (x[i] - self.mean[i]) as f64;
            acc -= s.ln() + 0.5 * d * d / (s * s);
        }
        acc
    }

    /// Draw x ~ N(mean, diag(sigmas²)).
    pub fn sample(&self, rng: &mut Rng) -> Vec<f32> {
        self.mean
            .iter()
            .zip(&self.sigmas)
            .map(|(m, s)| m + s * rng.normal() as f32)
            .collect()
    }

    /// Mahalanobis distance of x from the mean.
    pub fn mahalanobis(&self, x: &[f32]) -> f64 {
        self.mean
            .iter()
            .zip(&self.sigmas)
            .zip(x)
            .map(|((m, s), xi)| {
                let d = ((xi - m) / s) as f64;
                d * d
            })
            .sum::<f64>()
            .sqrt()
    }
}

/// Log-likelihood ratio log p(x)/q(x) for equal-sigma isotropic heads,
/// fused as sum((mu_q - mu_p) * (2x - mu_p - mu_q)) / (2 sigma^2) — the same
/// difference-of-squares factorization as the L1 Pallas kernel, avoiding the
/// cancellation of two large norms (paper §3.6 log-domain rule).
#[inline]
pub fn iso_log_ratio(x: &[f32], mu_p: &[f32], mu_q: &[f32], sigma: f64) -> f64 {
    debug_assert_eq!(x.len(), mu_p.len());
    debug_assert_eq!(x.len(), mu_q.len());
    let mut acc = 0.0f64;
    for i in 0..x.len() {
        let dq_dp = (mu_q[i] - mu_p[i]) as f64;
        let two_x = 2.0 * x[i] as f64 - mu_p[i] as f64 - mu_q[i] as f64;
        acc += dq_dp * two_x;
    }
    -acc / (2.0 * sigma * sigma)
}

/// Log ratio for diagonal heads (Remark 1): Mahalanobis difference plus the
/// log-determinant correction 1/2 log|Σ_q| - 1/2 log|Σ_p|.
pub fn diag_log_ratio(x: &[f32], p: &DiagGaussian, q: &DiagGaussian) -> f64 {
    p.log_density(x) - q.log_density(x)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest_lite::{check, NormalVec, UsizeRange};

    fn mc_overlap(p: &IsoGaussian, q: &IsoGaussian, n: usize, seed: u64) -> f64 {
        // E_q[min(1, p/q)] == beta for alpha = min(1, p/q).
        let mut rng = Rng::new(seed);
        let mut acc = 0.0;
        for _ in 0..n {
            let x = q.sample(&mut rng);
            let lr = p.log_density(&x) - q.log_density(&x);
            acc += lr.min(0.0).exp();
        }
        acc / n as f64
    }

    #[test]
    fn log_density_matches_analytic_1d() {
        let g = IsoGaussian::new(vec![0.0], 1.0);
        let want = -0.5 * (2.0 * std::f64::consts::PI).ln();
        assert!((g.log_density(&[0.0]) - want).abs() < 1e-12);
        assert!((g.log_density(&[1.0]) - (want - 0.5)).abs() < 1e-12);
    }

    #[test]
    fn closed_form_overlap_matches_monte_carlo() {
        let p = IsoGaussian::new(vec![0.5, -0.3, 0.2], 0.7);
        let q = IsoGaussian::new(vec![0.0, 0.0, 0.0], 0.7);
        let analytic = p.overlap(&q);
        let mc = mc_overlap(&p, &q, 60_000, 11);
        assert!(
            (analytic - mc).abs() < 0.01,
            "closed form {analytic:.4} vs MC {mc:.4}"
        );
    }

    #[test]
    fn overlap_one_for_identical_heads() {
        let p = IsoGaussian::new(vec![1.0, 2.0], 0.5);
        assert!((p.overlap(&p.clone()) - 1.0).abs() < 1e-6); // A&S erf bias ~1e-9
    }

    #[test]
    fn iso_log_ratio_matches_density_difference() {
        let mut rng = Rng::new(3);
        for _ in 0..50 {
            let d = 8;
            let mu_p: Vec<f32> = (0..d).map(|_| rng.normal() as f32).collect();
            let mu_q: Vec<f32> = (0..d).map(|_| rng.normal() as f32).collect();
            let x: Vec<f32> = (0..d).map(|_| rng.normal() as f32).collect();
            let sigma = 0.6;
            let p = IsoGaussian::new(mu_p.clone(), sigma);
            let q = IsoGaussian::new(mu_q.clone(), sigma);
            let direct = p.log_density(&x) - q.log_density(&x);
            let fused = iso_log_ratio(&x, &mu_p, &mu_q, sigma);
            assert!((direct - fused).abs() < 1e-4, "{direct} vs {fused}"); // f32 sub rounding
        }
    }

    #[test]
    fn sampling_moments() {
        let g = IsoGaussian::new(vec![2.0; 4], 0.5);
        let mut rng = Rng::new(7);
        let n = 20_000;
        let mut sum = 0.0f64;
        let mut sum2 = 0.0f64;
        for _ in 0..n {
            let x = g.sample(&mut rng);
            for v in x {
                sum += v as f64;
                sum2 += (v as f64 - 2.0).powi(2);
            }
        }
        let mean = sum / (n * 4) as f64;
        let var = sum2 / (n * 4) as f64;
        assert!((mean - 2.0).abs() < 0.01, "mean {mean}");
        assert!((var - 0.25).abs() < 0.01, "var {var}");
    }

    #[test]
    fn diag_reduces_to_iso_when_sigmas_equal() {
        let mean = vec![0.1, -0.2, 0.3];
        let iso = IsoGaussian::new(mean.clone(), 0.4);
        let diag = DiagGaussian::new(mean, vec![0.4; 3]);
        let x = [0.0, 0.5, -0.5];
        assert!((iso.log_density(&x) - diag.log_density(&x)).abs() < 1e-6); // f32 sigma rounding
    }

    #[test]
    fn prop_overlap_bounds_and_symmetry() {
        // beta in (0, 1], symmetric in (p, q).
        check(&NormalVec { len: UsizeRange(1, 16), scale: 1.0 }, |mean| {
            let p = IsoGaussian::new(mean.clone(), 0.5);
            let q = IsoGaussian::new(vec![0.0; mean.len()], 0.5);
            let b1 = p.overlap(&q);
            let b2 = q.overlap(&p);
            if !(0.0..=1.0 + 1e-12).contains(&b1) {
                return Err(format!("overlap {b1} out of bounds"));
            }
            if (b1 - b2).abs() > 1e-12 {
                return Err(format!("asymmetric: {b1} vs {b2}"));
            }
            Ok(())
        });
    }
}
