//! Seeded, config-gated fault injection for the serving stack.
//!
//! The paper pitches speculative decoding for latency-sensitive web
//! serving; a serving tier earns that claim only if its failure modes
//! are bounded and observable. This module provides the *chaos half* of
//! that story: a [`FaultPlan`] — off by default, zero-cost when disabled
//! — that deterministically injects the three failure shapes the
//! fault-tolerance layer must absorb:
//!
//! * **panics** — a model forward aborts mid-decode (replica supervision
//!   must answer the group and restart the stacks);
//! * **stalls** — a forward blocks for a bounded interval (deadline
//!   machinery and the soak's no-hang criterion must absorb it);
//! * **non-finite outputs** — a forward returns NaN rows (the engine's
//!   numeric guards must convert them to typed errors before the
//!   acceptance scan, never serve them).
//!
//! Determinism: every injection decision is a pure function of
//! `(plan seed, site, op index)` via a splitmix64 hash — no global RNG,
//! no time dependence — so a chaos run is replayable from its config.
//! The per-op cost when enabled is one relaxed atomic increment plus a
//! hash; when `FaultConfig::enabled` is false no [`FaultPlan`] is ever
//! constructed and the hot path is untouched.
//!
//! Wiring: the replica pool wraps each replica's backends in
//! [`FaultyBackend`] when the plan is armed (see `server::sched`), so
//! faults enter at the session boundary exactly where a misbehaving
//! model would. [`FaultyBackend::as_native`] intentionally returns
//! `None`: sessions over a faulty backend route through the stateless
//! wrapper (observationally identical decodes), which keeps every
//! forward — cached config or not — flowing through the injection
//! point.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Duration;

use anyhow::Result;

use crate::models::Backend;

/// Which boundary a fault is injected at (also salts the decision hash,
/// so target and draft streams fault independently under one seed).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FaultSite {
    /// The target (verifier) backend's forwards.
    Target,
    /// The draft (proposal) backend's forwards.
    Draft,
    /// Registry blob bytes in transit (pull path). Drawn from its own
    /// op stream via [`FaultPlan::draw_blob_corrupt`], never from the
    /// forward-fault chain.
    BlobCorrupt,
}

impl FaultSite {
    fn salt(self) -> u64 {
        match self {
            FaultSite::Target => 0x7A26_57E7,
            FaultSite::Draft => 0xD2AF_7001,
            FaultSite::BlobCorrupt => 0x5EED_B10B,
        }
    }

    /// Stable lowercase label (metrics / logs).
    pub fn as_str(self) -> &'static str {
        match self {
            FaultSite::Target => "target",
            FaultSite::Draft => "draft",
            FaultSite::BlobCorrupt => "blob",
        }
    }
}

/// One injected fault.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Fault {
    /// Abort the forward with a panic (replica supervision test).
    Panic,
    /// Sleep for the configured interval before the forward proceeds.
    Stall(Duration),
    /// Poison the forward's tip row with NaN (numeric-guard test).
    NonFinite,
}

/// Fault-injection configuration (a `ServeConfig` sub-object; JSON key
/// `"fault"`). Disabled by default; validation bounds every knob so a
/// chaos run cannot wedge the server (stalls are capped, fault budgets
/// are finite when set).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct FaultConfig {
    /// Master gate. When false no plan is built and serving is
    /// byte-for-byte the non-chaos path.
    pub enabled: bool,
    /// Seed for the injection schedule (replayability).
    pub seed: u64,
    /// Per-forward probability of an injected panic.
    pub p_panic: f64,
    /// Per-forward probability of an injected stall.
    pub p_stall: f64,
    /// Stall duration in milliseconds (bounded; see [`FaultConfig::validate`]).
    pub stall_ms: u64,
    /// Per-forward probability of a NaN-poisoned output row.
    pub p_nan: f64,
    /// Per-pull probability that a registry blob's bytes are corrupted
    /// in transit (one deterministically-chosen byte is flipped). The
    /// digest check must reject the blob with a typed
    /// `digest_mismatch`, never load it. Drawn from its own op stream —
    /// it does not dilute the forward-fault sub-distribution.
    pub p_blob_corrupt: f64,
    /// Hard cap on total injected faults (0 = unlimited). A finite
    /// budget gives chaos tests a guaranteed-quiescent tail to measure
    /// recovery against.
    pub max_faults: u64,
}

impl Default for FaultConfig {
    fn default() -> Self {
        FaultConfig {
            enabled: false,
            seed: 0xFA_0175,
            p_panic: 0.0,
            p_stall: 0.0,
            stall_ms: 25,
            p_nan: 0.0,
            p_blob_corrupt: 0.0,
            max_faults: 0,
        }
    }
}

impl FaultConfig {
    /// Bounds-check the plan: probabilities must form a sub-distribution
    /// and stalls must be short enough that a faulted forward cannot
    /// outlive the serving timeout.
    pub fn validate(&self) -> Result<()> {
        for (name, p) in [
            ("p_panic", self.p_panic),
            ("p_stall", self.p_stall),
            ("p_nan", self.p_nan),
            ("p_blob_corrupt", self.p_blob_corrupt),
        ] {
            anyhow::ensure!(
                p.is_finite() && (0.0..=1.0).contains(&p),
                "fault {name} must be in [0, 1], got {p}"
            );
        }
        anyhow::ensure!(
            self.p_panic + self.p_stall + self.p_nan <= 1.0 + 1e-12,
            "fault probabilities must sum to at most 1"
        );
        anyhow::ensure!(
            self.stall_ms <= 10_000,
            "fault stall_ms must be <= 10000 (a stalled forward must not \
             outlive the serving timeout), got {}",
            self.stall_ms
        );
        Ok(())
    }
}

fn splitmix64(mut x: u64) -> u64 {
    x = x.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = x;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// A live injection schedule shared by every replica: decisions are
/// drawn per forward from the seeded hash, counted per kind, and capped
/// by the configured budget.
pub struct FaultPlan {
    cfg: FaultConfig,
    ops: AtomicU64,
    blob_ops: AtomicU64,
    injected: AtomicU64,
    panics: AtomicU64,
    stalls: AtomicU64,
    nans: AtomicU64,
    corrupts: AtomicU64,
}

impl FaultPlan {
    /// Build a plan from a validated config. Callers gate on
    /// `cfg.enabled` — a disabled config never constructs a plan.
    pub fn new(cfg: FaultConfig) -> Result<Arc<FaultPlan>> {
        cfg.validate()?;
        anyhow::ensure!(cfg.enabled, "FaultPlan requires an enabled FaultConfig");
        Ok(Arc::new(FaultPlan {
            cfg,
            ops: AtomicU64::new(0),
            blob_ops: AtomicU64::new(0),
            injected: AtomicU64::new(0),
            panics: AtomicU64::new(0),
            stalls: AtomicU64::new(0),
            nans: AtomicU64::new(0),
            corrupts: AtomicU64::new(0),
        }))
    }

    /// The plan's configuration.
    pub fn config(&self) -> &FaultConfig {
        &self.cfg
    }

    /// Total faults injected so far.
    pub fn injected(&self) -> u64 {
        self.injected.load(Ordering::Relaxed)
    }

    /// Injected panics so far.
    pub fn panics(&self) -> u64 {
        self.panics.load(Ordering::Relaxed)
    }

    /// Injected stalls so far.
    pub fn stalls(&self) -> u64 {
        self.stalls.load(Ordering::Relaxed)
    }

    /// Injected NaN poisonings so far.
    pub fn nans(&self) -> u64 {
        self.nans.load(Ordering::Relaxed)
    }

    /// Injected blob corruptions so far.
    pub fn corrupts(&self) -> u64 {
        self.corrupts.load(Ordering::Relaxed)
    }

    /// True once the fault budget (when finite) is exhausted — the
    /// quiescent tail a recovery measurement waits for.
    pub fn exhausted(&self) -> bool {
        self.cfg.max_faults > 0 && self.injected() >= self.cfg.max_faults
    }

    /// Draw the fault decision for the next forward at `site`. Pure in
    /// `(seed, site, op index)`; respects the fault budget.
    pub fn draw(&self, site: FaultSite) -> Option<Fault> {
        let op = self.ops.fetch_add(1, Ordering::Relaxed);
        if self.cfg.max_faults > 0 && self.injected.load(Ordering::Relaxed) >= self.cfg.max_faults
        {
            return None;
        }
        let h = splitmix64(self.cfg.seed ^ site.salt().wrapping_mul(0x100_0000_01B3) ^ op);
        // 53-bit mantissa keeps the u64 -> f64 map uniform on [0, 1).
        let u = (h >> 11) as f64 / (1u64 << 53) as f64;
        let fault = if u < self.cfg.p_panic {
            self.panics.fetch_add(1, Ordering::Relaxed);
            Some(Fault::Panic)
        } else if u < self.cfg.p_panic + self.cfg.p_stall {
            self.stalls.fetch_add(1, Ordering::Relaxed);
            Some(Fault::Stall(Duration::from_millis(self.cfg.stall_ms)))
        } else if u < self.cfg.p_panic + self.cfg.p_stall + self.cfg.p_nan {
            self.nans.fetch_add(1, Ordering::Relaxed);
            Some(Fault::NonFinite)
        } else {
            None
        };
        if fault.is_some() {
            self.injected.fetch_add(1, Ordering::Relaxed);
        }
        fault
    }

    /// Draw the corruption decision for the next pulled blob. Pure in
    /// `(seed, BlobCorrupt salt, blob-op index)`; respects the shared
    /// fault budget. `Some(h)` means "corrupt this blob", with `h` the
    /// decision hash the caller uses to pick the byte to flip (see
    /// [`FaultPlan::corrupt_blob`]).
    pub fn draw_blob_corrupt(&self) -> Option<u64> {
        let op = self.blob_ops.fetch_add(1, Ordering::Relaxed);
        if self.cfg.max_faults > 0 && self.injected.load(Ordering::Relaxed) >= self.cfg.max_faults
        {
            return None;
        }
        let h = splitmix64(
            self.cfg.seed ^ FaultSite::BlobCorrupt.salt().wrapping_mul(0x100_0000_01B3) ^ op,
        );
        let u = (h >> 11) as f64 / (1u64 << 53) as f64;
        if u < self.cfg.p_blob_corrupt {
            self.corrupts.fetch_add(1, Ordering::Relaxed);
            self.injected.fetch_add(1, Ordering::Relaxed);
            Some(splitmix64(h))
        } else {
            None
        }
    }

    /// Apply the blob-corruption draw to `bytes`: flips one
    /// deterministically-chosen byte when the draw fires. Returns true
    /// when the blob was corrupted — the pull path feeds the mutated
    /// bytes to digest verification, which must reject them.
    pub fn corrupt_blob(&self, bytes: &mut [u8]) -> bool {
        match self.draw_blob_corrupt() {
            Some(h) if !bytes.is_empty() => {
                let idx = (h % bytes.len() as u64) as usize;
                bytes[idx] ^= 0xFF;
                true
            }
            _ => false,
        }
    }
}

/// A [`Backend`] decorator that applies a [`FaultPlan`] to every
/// forward. Wraps a replica's target/draft stacks when chaos is armed;
/// never constructed otherwise.
pub struct FaultyBackend {
    inner: Box<dyn Backend>,
    plan: Arc<FaultPlan>,
    site: FaultSite,
}

impl FaultyBackend {
    /// Wrap `inner` so its forwards consult `plan` at `site`.
    pub fn wrap(inner: Box<dyn Backend>, plan: Arc<FaultPlan>, site: FaultSite) -> Box<dyn Backend> {
        Box::new(FaultyBackend { inner, plan, site })
    }

    fn apply(&self, fault: Option<Fault>) -> bool {
        match fault {
            Some(Fault::Panic) => {
                panic!(
                    "injected fault: panic at {} forward (seeded chaos plan)",
                    self.site.as_str()
                );
            }
            Some(Fault::Stall(d)) => {
                std::thread::sleep(d);
                false
            }
            Some(Fault::NonFinite) => true,
            None => false,
        }
    }

    /// Poison the tip row (the last `patch` values of every sequence's
    /// output) — exactly the row the decode loops read next, so the
    /// numeric guards must face it.
    fn poison_tip(&self, out: &mut [f32], rows: usize) {
        let p = self.inner.patch();
        if rows == 0 || out.len() < p {
            return;
        }
        let stride = out.len() / rows.max(1);
        for r in 0..rows {
            let end = (r + 1) * stride;
            for v in &mut out[end - p..end] {
                *v = f32::NAN;
            }
        }
    }
}

impl Backend for FaultyBackend {
    fn name(&self) -> &str {
        self.inner.name()
    }

    fn patch(&self) -> usize {
        self.inner.patch()
    }

    fn max_ctx(&self) -> usize {
        self.inner.max_ctx()
    }

    fn forward(&self, tokens: &[f32], n: usize) -> Result<Vec<f32>> {
        let poison = self.apply(self.plan.draw(self.site));
        let mut out = self.inner.forward(tokens, n)?;
        if poison {
            self.poison_tip(&mut out, 1);
        }
        Ok(out)
    }

    fn forward_batch(&self, tokens: &[f32], b: usize, n: usize) -> Result<Vec<f32>> {
        let poison = self.apply(self.plan.draw(self.site));
        let mut out = self.inner.forward_batch(tokens, b, n)?;
        if poison {
            self.poison_tip(&mut out, b);
        }
        Ok(out)
    }

    fn mean_secs(&self) -> f64 {
        self.inner.mean_secs()
    }

    fn flops(&self, n: usize) -> f64 {
        self.inner.flops(n)
    }

    // Intentionally no `as_native` override: sessions over a faulty
    // backend use the stateless wrapper, keeping every forward on the
    // injection path.
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::AnalyticBackend;

    fn cfg(p_panic: f64, p_stall: f64, p_nan: f64) -> FaultConfig {
        FaultConfig {
            enabled: true,
            seed: 7,
            p_panic,
            p_stall,
            stall_ms: 1,
            p_nan,
            p_blob_corrupt: 0.0,
            max_faults: 0,
        }
    }

    #[test]
    fn disabled_config_is_rejected_and_validated() {
        assert!(FaultPlan::new(FaultConfig::default()).is_err());
        let mut bad = cfg(0.5, 0.4, 0.3);
        assert!(bad.validate().is_err()); // sums to 1.2
        bad.p_panic = 0.1;
        assert!(bad.validate().is_ok());
        let mut stall = cfg(0.0, 1.0, 0.0);
        stall.stall_ms = 60_000;
        assert!(stall.validate().is_err());
    }

    #[test]
    fn schedule_is_deterministic_in_seed_and_op_index() {
        let a = FaultPlan::new(cfg(0.2, 0.2, 0.2)).unwrap();
        let b = FaultPlan::new(cfg(0.2, 0.2, 0.2)).unwrap();
        let da: Vec<Option<Fault>> = (0..200).map(|_| a.draw(FaultSite::Target)).collect();
        let db: Vec<Option<Fault>> = (0..200).map(|_| b.draw(FaultSite::Target)).collect();
        assert_eq!(da, db);
        assert!(da.iter().any(|f| f.is_some()), "no faults drawn at p = 0.6");
        assert!(da.iter().any(|f| f.is_none()), "every op faulted at p = 0.6");
        // A different seed produces a different schedule.
        let mut c2 = cfg(0.2, 0.2, 0.2);
        c2.seed = 8;
        let c = FaultPlan::new(c2).unwrap();
        let dc: Vec<Option<Fault>> = (0..200).map(|_| c.draw(FaultSite::Target)).collect();
        assert_ne!(da, dc);
    }

    #[test]
    fn budget_caps_total_injections() {
        let mut c = cfg(0.0, 0.0, 1.0);
        c.max_faults = 3;
        let plan = FaultPlan::new(c).unwrap();
        let hits = (0..50).filter(|_| plan.draw(FaultSite::Draft).is_some()).count();
        assert_eq!(hits, 3);
        assert!(plan.exhausted());
        assert_eq!(plan.nans(), 3);
    }

    #[test]
    fn nan_injection_poisons_only_the_tip_row() {
        let inner = AnalyticBackend::new("t", 2, 0.8, 0.1);
        let mut c = cfg(0.0, 0.0, 1.0);
        c.max_faults = 1;
        let plan = FaultPlan::new(c).unwrap();
        let b = FaultyBackend::wrap(Box::new(inner), plan.clone(), FaultSite::Target);
        let toks = [0.5f32, -0.5, 0.2, 0.1]; // 2 patches of size 2
        let out = b.forward(&toks, 2).unwrap();
        assert_eq!(out.len(), 4);
        assert!(out[..2].iter().all(|v| v.is_finite()), "prefix rows must stay clean");
        assert!(out[2..].iter().all(|v| v.is_nan()), "tip row must be poisoned");
        // Budget spent: the next forward is clean.
        let out2 = b.forward(&toks, 2).unwrap();
        assert!(out2.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn blob_corruption_is_deterministic_and_flips_one_byte() {
        let mut c = cfg(0.0, 0.0, 0.0);
        c.p_blob_corrupt = 1.0;
        let plan = FaultPlan::new(c).unwrap();
        let clean: Vec<u8> = (0..64u8).collect();
        let mut a = clean.clone();
        assert!(plan.corrupt_blob(&mut a));
        assert_eq!(plan.corrupts(), 1);
        // Exactly one byte differs, by exactly a bit-flip.
        let diffs: Vec<usize> = (0..clean.len()).filter(|&i| a[i] != clean[i]).collect();
        assert_eq!(diffs.len(), 1);
        assert_eq!(a[diffs[0]], clean[diffs[0]] ^ 0xFF);
        // Same seed + same op index -> same corruption site.
        let plan2 = FaultPlan::new(c).unwrap();
        let mut b = clean.clone();
        assert!(plan2.corrupt_blob(&mut b));
        assert_eq!(a, b);
        // p = 0 never corrupts, and empty blobs are left alone.
        let clean_plan = FaultPlan::new(cfg(0.0, 0.0, 0.0)).unwrap();
        let mut c2 = clean.clone();
        assert!(!clean_plan.corrupt_blob(&mut c2));
        assert_eq!(c2, clean);
        assert!(!plan.corrupt_blob(&mut []));
    }

    #[test]
    fn blob_corruption_respects_the_shared_budget() {
        let mut c = cfg(0.0, 0.0, 0.0);
        c.p_blob_corrupt = 1.0;
        c.max_faults = 2;
        let plan = FaultPlan::new(c).unwrap();
        let hits = (0..10)
            .filter(|_| {
                let mut b = vec![1u8, 2, 3, 4];
                plan.corrupt_blob(&mut b)
            })
            .count();
        assert_eq!(hits, 2);
        assert!(plan.exhausted());
    }

    #[test]
    fn panic_fault_panics_with_a_recognizable_message() {
        let inner = AnalyticBackend::new("t", 1, 0.8, 0.0);
        let plan = FaultPlan::new(cfg(1.0, 0.0, 0.0)).unwrap();
        let b = FaultyBackend::wrap(Box::new(inner), plan, FaultSite::Draft);
        let err = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let _ = b.forward(&[0.1f32], 1);
        }))
        .unwrap_err();
        let msg = err.downcast_ref::<String>().cloned().unwrap_or_default();
        assert!(msg.contains("injected fault"), "{msg}");
    }
}
