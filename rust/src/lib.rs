//! # STRIDE — Speculative decoding for time-series foundation models
//!
//! Rust + JAX + Pallas reproduction of *"Accelerating Time Series Foundation
//! Models with Speculative Decoding"* (CS.LG 2025) as a production-shaped
//! serving framework.
//!
//! Architecture (see DESIGN.md):
//! * **L1 (Pallas)** and **L2 (JAX)** live in `python/compile/` and run only
//!   at build time (`make artifacts`), producing HLO-text artifacts.
//! * **L3 (this crate)** is the serving coordinator: PJRT runtime, the
//!   speculative-decoding engine (practical + lossless variants), the
//!   dynamic batcher and router, theory-driven γ selection, and metrics.
//!
//! Start with the repo's root `README.md` (quickstart + layer map) and
//! `docs/ARCHITECTURE.md` (one page per layer: session model, kernel
//! ownership, threading, batcher grouping, controller design). This crate
//! enforces `#![warn(missing_docs)]`; `scripts/ci.sh` turns rustdoc
//! warnings into failures.
//!
//! Quick tour:
//! * [`specdec`] — Algorithm 1/2 over a [`models::Backend`], driven
//!   through KV-cached decode sessions.
//! * [`specdec::GammaController`] — the **adaptive speculation
//!   controller**: per-stream EWMA α̂ over live acceptance telemetry
//!   (rollback-aware — rejected proposals count at the weight the rule
//!   gave them), measured draft/target cost ratio, and the closed-form
//!   speedup curve re-evaluated online to retune γ (hysteresis-gated, so
//!   no thrash) and optionally σ inside an MSE guard-rail. Adaptation
//!   changes *when* drafting happens, never *what* is emitted —
//!   [`specdec::sd_generate_scheduled`] replays a decode's per-round γ
//!   choices bit-identically (`tests/statistical.rs`). Serving: the
//!   batcher keys adaptive jobs on a long-lived controller's current
//!   recommendation (jobs regroup as γ drifts), `/stats` and
//!   `stride_controller_*` gauges expose the live state, and
//!   `benches/adaptive_gamma.rs` pins the controller within 90% of the
//!   best fixed γ on drifting-α workloads.
//! * [`specdec::draft`] — **pluggable draft sources**: where proposals
//!   come from is a trait ([`specdec::DraftSource`]), not a hard-wired
//!   second model. [`specdec::ModelDraft`] wraps any backend's decode
//!   session and is bit-identical to the pre-refactor engine
//!   (`tests/draft_equivalence.rs` keeps the old loops verbatim as the
//!   baseline); [`specdec::ExtrapolationDraft`] drafts for free from a
//!   closed-form linear/seasonal continuation (measured cost ratio
//!   c ≈ 0, the Eq. 5 best case); [`specdec::AdaptiveResidualDraft`]
//!   NLMS-fits a residual head to the target means observed during
//!   verification — acceptance α rises *online* with zero extra target
//!   passes, updates pause while speculation is in flight and flush
//!   after rollback. Selected via `SpecConfig::draft` / `--draft` /
//!   config `"draft"` / per-request `"draft"`; SD decode groups key on
//!   the kind; `/stats` and `stride_draft_*` gauges report per-source
//!   α̂/c/update counts; `benches/draft_sources.rs` pins the adaptive
//!   head out-accepting a frozen model draft after regime drift and the
//!   extrapolation source measuring the lowest c.
//! * [`specdec::sd_generate_tree`] — **tree speculation**: k candidate
//!   draft branches per round ([`specdec::DraftSource::propose_k`]),
//!   verified against *one* shared-prefix target session by per-branch
//!   extend + rollback; the longest accepted run commits (deterministic
//!   lowest-index tie-break). Expected block length follows the
//!   max-of-k law `E[L_k] = 1 + Σᵢ(1 − (1 − αⁱ)ᵏ)`
//!   ([`theory::expected_block_length_tree`]); the controller retunes
//!   (γ × k) jointly via [`theory::optimal_gamma_k`]. The k = 1 path is
//!   bit-identical to classic speculation across the full
//!   variant × emission × cache × draft-kind matrix
//!   (`tests/tree_equivalence.rs` — the equivalence wall), which is why
//!   [`specdec::sd_generate`] safely routes through it whenever
//!   `SpecConfig::k > 1`. Lossless requires k = 1 (residual thinning
//!   corrects one proposal law, not a max-of-k mixture — rejected
//!   loudly, never clamped). Serving: per-request `"k"`, a k axis in
//!   the decode-group key (k > 1 groups decode per-job through the
//!   tree path), `stride_tree_*` metrics + the `/stats` `"tree"` block,
//!   and `benches/tree_speculation.rs` pins k = 4 out-running k = 1
//!   per acceptance regime in `results/BENCH_tree_speculation.json`.
//! * [`models`] — backends + the decode-session layer:
//!   [`models::begin_session`] hands out a [`models::DecodeSession`]
//!   (`extend`/`rollback`/`evict_to`) that is KV-cached on the native
//!   backend ([`models::CacheMode::On`], the default) or a stateless
//!   re-forward wrapper (`Off`, the uncached A/B baseline and the only
//!   mode for fixed-shape PJRT executables). Rollback semantics: a
//!   rejected speculation truncates the session (and its K/V buffers) —
//!   the surviving prefix stays valid because attention is causal; a
//!   window slide past `max_ctx` instead re-prefills the kept suffix
//!   (learned absolute positions shift). Cache on/off is observationally
//!   identical — same means, same acceptance decisions, same RNG stream
//!   (`tests/cache_equivalence.rs`, `tests/statistical.rs`); only
//!   wall-clock differs, reported by the `perf_hotpath` bench's
//!   cached-vs-uncached sweep (`results/perf_hotpath_cached.csv`).
//!   Toggle: `ServeConfig::cache` / `--no-cache` / per-request
//!   `"cache": false` / `SpecConfig::cache`.
//! * [`nn::kernel`] — the native backend's **kernel layer**: weights are
//!   resolved once at model construction into packed `Arc<Tensor>` handles
//!   ([`nn::PackedWeights`] — no string-keyed lookups in any hot loop), a
//!   [`nn::ForwardScratch`] arena owned by the [`nn::KvCache`] makes the
//!   steady-state cached forward zero-allocation, and matmuls dispatch
//!   serial (register-blocked micro-kernel) or row-parallel over the
//!   shared [`util::threadpool::global_pool`] — bitwise identical for any
//!   thread count (`--threads` / `STRIDE_THREADS`). The pre-kernel-layer
//!   implementation survives behind a reference flag as the equivalence
//!   baseline (`tests/kernel_equivalence.rs`,
//!   `tests/alloc_discipline.rs`, and `results/BENCH_perf_hotpath.json`
//!   pin correctness and the perf trajectory). Batched verify fans
//!   per-sequence extends across the same pool, so a lockstep round costs
//!   max-of-sequences wall clock instead of sum.
//! * [`theory`] — Eqs. 2–6 closed forms, γ* rule, dependence bounds.
//! * [`accept`] — log-space acceptance (Eq. 7) + the α̂ estimator (§3.5).
//! * [`runtime`] — HLO-text → PJRT executable cache.
//! * [`server`] — HTTP front end with dynamic batching; SD jobs are
//!   grouped by (γ, σ, cache, adaptive, draft kind) and each group's
//!   sequences keep their decode sessions across all speculative rounds.
//! * [`server::sched`] — the **serving scheduler**: a bounded admission
//!   queue with load shedding (HTTP 429 + `Retry-After`; a saturated
//!   queue evicts its worst job for a higher-priority arrival),
//!   per-request priorities and deadlines (expired jobs fail fast with
//!   HTTP 504 and never decode), earliest-deadline-first dispatch
//!   within each compatibility group, and an engine **replica pool** —
//!   N model/session stacks over one `Arc`-packed weight storage
//!   ([`models::NativeBackend::replicate`]) with group-affinity routing
//!   plus idle stealing, merged draft heads, and a shared γ controller.
//!   Decode groups run through [`specdec::sd_generate_stream_seeded`]
//!   (per-request seeds, per-sequence γ bucketing), so every response
//!   is bit-identical to [`specdec::sd_generate_from`] at the same
//!   seed for any replica count or batch composition. `/healthz` is a
//!   readiness probe (503 while saturated); `benches/serving_load.rs`
//!   pins throughput scaling, overload SLO attainment, and the
//!   determinism contract in `results/BENCH_serving_load.json`.
//! * [`faultinject`] + the **fault-tolerance layer**: a seeded,
//!   config-gated chaos plan (panics / stalls / NaN outputs at the
//!   session boundary), `catch_unwind` replica supervision with typed
//!   [`server::ServeError::ReplicaFailure`] replies, requeue-once for
//!   innocent group-mates and stack rebinds over the shared packed
//!   weights, numeric guards in every decode loop (non-finite model
//!   output becomes a typed error before the acceptance scan — never a
//!   served NaN), a speculation **circuit breaker** in the adaptive
//!   controller (α̂ collapse or a non-finite streak trips serving to
//!   the pure-AR γ=0 fallback, recovering through half-open probe
//!   rounds), and graceful drain shutdown (`/healthz` reports
//!   `"draining"`). `tests/fault_injection.rs` is the chaos suite;
//!   `benches/chaos_soak.rs` pins no-hang/no-NaN/bounded-recovery in
//!   `results/BENCH_chaos_soak.json`.
//! * [`trace`] — the **flight recorder**: a config-gated
//!   (`--trace-capacity`), fixed-capacity ring of typed serving events.
//!   Every request carries a seeded `request_id` (echoed in the JSON
//!   body and `X-Request-Id`, client-overridable) and leaves a full
//!   timeline — admission, queue-wait span, each speculative round's
//!   (γ, k, per-proposal α, draft-vs-verify ns), reply — alongside
//!   control-plane events (retunes, breaker flips, replica restarts,
//!   steals, swap generations). `GET /debug/trace` exports the ring as
//!   Chrome trace-event JSON (`chrome://tracing` / Perfetto),
//!   `GET /debug/requests/<id>` reconstructs one request, `/stats`
//!   reports recorded/dropped. Disabled tracing constructs nothing and
//!   serves bit-identically ([`specdec::with_round_observer`] is the
//!   engine-side hook: a thread-local checked once per round);
//!   enabled tracing never allocates per event (fixed `Copy` ring
//!   slots; overflow overwrites oldest, exactly counted).
//! * [`registry`] — the **content-addressed model registry**: versioned
//!   manifests (per-blob SHA-256 over a hand-rolled FIPS-checked
//!   [`registry::digest`]), a digest-keyed blob cache, push/pull over
//!   the serving HTTP API (`/v1/models`, `/v1/blobs` — pulls reuse the
//!   seeded [`http::RetryPolicy`]), and verify-then-bind **zero-copy
//!   loading**: blobs are mmapped ([`util::mmap::MappedFile`], heap
//!   fallback where unsupported), hashed in place, and weight tensors
//!   bind straight into the mapping ([`nn::Weights::from_mapped`]) — no
//!   float is copied between disk and the packed kernel handles, and
//!   mapped loads are bit-identical to heap loads. On top of it sits
//!   **live weight swap**: `POST /admin/swap` resolves a manifest,
//!   preloads + verifies, then replicas drain their current decode
//!   groups and rebind to the new `Arc`-packed weights with zero
//!   dropped requests (draft heads and controller state reset or carry
//!   per `ServeConfig::swap_heads`); `stride_model_swap_*` metrics and
//!   the serving digest in `/healthz` + `/stats` make the cutover
//!   observable. `tests/registry_e2e.rs` pins push→pull bit-identity,
//!   typed corrupt-blob rejection, and post-swap outputs bit-identical
//!   to a cold start; `benches/model_swap.rs` pins zero-drop + bounded
//!   p99 during a mid-soak hot swap.

#![warn(missing_docs)]

pub mod accept;
pub mod config;
pub mod data;
pub mod faultinject;
pub mod forecast;
pub mod gaussian;
pub mod http;
pub mod metrics;
pub mod models;
pub mod nn;
pub mod registry;
pub mod repro;
pub mod runtime;
pub mod server;
pub mod specdec;
pub mod theory;
pub mod trace;
pub mod util;
pub mod xla;

/// Crate version string surfaced by the CLI and `/healthz`.
pub const VERSION: &str = env!("CARGO_PKG_VERSION");

/// Resolve the artifacts directory: `STRIDE_ARTIFACTS` env var or
/// `<manifest>/artifacts`.
pub fn artifacts_dir() -> std::path::PathBuf {
    match std::env::var("STRIDE_ARTIFACTS") {
        Ok(p) => std::path::PathBuf::from(p),
        Err(_) => std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts"),
    }
}
