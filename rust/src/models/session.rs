//! Decode sessions: incremental decoding over a [`Backend`].
//!
//! A session owns one sequence's decode state (context tokens plus, for
//! KV-cached implementations, per-layer K/V buffers) and exposes the four
//! operations a speculative round needs:
//!
//! * [`DecodeSession::tip_mean`] — the model's prediction of the next patch
//!   given the current context (μ at the tip);
//! * [`DecodeSession::extend`] — append `k` patches and get the `k+1`
//!   prefix-conditional means covering them plus one beyond (exactly what
//!   target validation of γ proposals needs: μ_p(0..γ) in one call);
//! * [`DecodeSession::rollback`] — forget the last `k` patches (rejected
//!   speculation) without touching the surviving prefix;
//! * [`DecodeSession::append`] — append without requiring means (emitted
//!   patches; stateless implementations defer the forward entirely).
//!
//! Two implementations exist: the stateless wrappers in this file (cache
//! off — every read re-forwards the full context, the paper's baseline cost
//! model, and the only option for fixed-shape PJRT executables), and the
//! KV-cached `NativeSession`/`NativeBatchSession` in `models::native`
//! (cache on — O(k·n·d) per read instead of O(n²·d), allocation-free in
//! steady state, and with batched reads fanned across the shared worker
//! pool so a lockstep round costs max-of-sequences — see the kernel-layer
//! section of `models/README.md`).
//!
//! Cache on/off must be *observationally identical*: same means (to float
//! equality on the native backend), same acceptance decisions, same RNG
//! stream. `rust/tests/cache_equivalence.rs` and the statistical suite pin
//! this.

use anyhow::Result;

use super::Backend;

/// Whether decode loops run over KV-cached sessions (`On`) or re-forward
/// the full context on every read (`Off` — the uncached baseline used for
/// A/B speedup measurement and for backends without a cached path).
///
/// `On` is a *request*, not a guarantee: backends without an incremental
/// implementation (XLA fixed-shape executables, analytic heads) silently
/// fall back to the stateless session, which is always correct.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum CacheMode {
    /// Incremental KV-cached sessions where the backend supports them.
    #[default]
    On,
    /// Stateless re-forward sessions (the baseline cost model).
    Off,
}

/// One sequence's incremental decode state over a backend.
///
/// Position/means convention: a session of length `n` holds patches
/// `0..n`; the model output at position `i` is the predicted mean of patch
/// `i+1`. `extend(patches, k)` therefore returns `(k+1)·patch` floats: the
/// outputs at positions `n-1 ..= n+k-1`, i.e. the mean of every appended
/// patch's position *and* the one beyond (the bonus patch of a fully
/// accepted speculative round).
pub trait DecodeSession {
    /// Values per patch token.
    fn patch(&self) -> usize;
    /// Patches currently in the session context.
    fn len(&self) -> usize;
    /// Whether the context holds no patches.
    fn is_empty(&self) -> bool {
        self.len() == 0
    }
    /// The backend's context capacity (window size for eviction).
    fn max_ctx(&self) -> usize;
    /// The raw context tokens (flat `[len, patch]`) — introspection for
    /// tests and for cross-session consistency checks.
    fn context(&self) -> &[f32];
    /// Predicted mean of the next patch given the current context.
    /// Stateless sessions may run a full forward here if stale.
    fn tip_mean(&mut self) -> Result<Vec<f32>>;
    /// Append `k` patches (flat `[k, patch]`); returns the `(k+1)·patch`
    /// means at positions `len-1 ..= len+k-1` (see trait docs). Slides the
    /// window first if the result would exceed `max_ctx`.
    fn extend(&mut self, patches: &[f32], k: usize) -> Result<Vec<f32>>;
    /// Append `k` patches without requiring means. Cached sessions compute
    /// incrementally anyway (cheap); stateless sessions just buffer and
    /// defer the forward to the next read.
    fn append(&mut self, patches: &[f32], k: usize) -> Result<()>;
    /// Forget the last `k` patches (rejected speculation). The surviving
    /// prefix — including any cached K/V — stays valid because attention
    /// is causal. Must leave at least one patch.
    fn rollback(&mut self, k: usize) -> Result<()>;
    /// Slide the window from the front so exactly `keep` patches remain —
    /// the stateless sliding-window rule. Cached sessions re-prefill the
    /// kept suffix (absolute positions shift, invalidating cached K/V).
    fn evict_to(&mut self, keep: usize) -> Result<()>;
    /// Sequential forward passes run so far (perf accounting).
    fn forwards(&self) -> usize;
    /// Verify `b` candidate branch suffixes of `k` patches each (flat
    /// `[b, k, patch]`, lane-major) against the current context in ONE
    /// stacked forward, **without changing session state**. On success,
    /// fills `out` with flat `[b, k+1, patch]` means — per branch, the
    /// same `(k+1)`-row convention as [`DecodeSession::extend`] (row 0 is
    /// the shared tip mean) — and returns `true`.
    ///
    /// The default returns `Ok(false)`: "no stacked path here" — the
    /// caller (the tree engine) falls back to sequential per-branch
    /// extend + rollback, which is retained as the reference and must
    /// stay bit-identical (`tests/tree_equivalence.rs`). Implementations
    /// must consume no RNG and produce rows bitwise equal to the
    /// sequential fallback's. `out` is caller-reused across rounds so the
    /// steady state stays allocation-free.
    fn verify_stacked(
        &mut self,
        branches: &[f32],
        b: usize,
        k: usize,
        out: &mut Vec<f32>,
    ) -> Result<bool> {
        let _ = (branches, b, k, out);
        Ok(false)
    }
}

/// Lockstep decode state for `b` independent sequences. Mirrors
/// [`DecodeSession`], but reads are batched over an explicit index set so
/// a continuous batcher can advance any subset of live sequences per
/// round, and writes (append/rollback/evict) are per-sequence because
/// acceptance lengths diverge.
pub trait BatchDecodeSession {
    /// Number of sequences in the batch.
    fn batch(&self) -> usize;
    /// Values per patch token.
    fn patch(&self) -> usize;
    /// Context length (patches) of sequence `i`.
    fn len(&self, i: usize) -> usize;
    /// The backend's context capacity (shared by all sequences).
    fn max_ctx(&self) -> usize;
    /// Tip means for the sequences in `idx` (flat `[idx.len(), patch]`).
    fn tip_means(&mut self, idx: &[usize]) -> Result<Vec<f32>>;
    /// Append `k` patches to each sequence in `idx` (flat
    /// `[idx.len(), k, patch]`); returns flat `[idx.len(), k+1, patch]`
    /// means with the same per-sequence convention as
    /// [`DecodeSession::extend`].
    fn extend(&mut self, idx: &[usize], patches: &[f32], k: usize) -> Result<Vec<f32>>;
    /// Append `k` patches to sequence `i` without requiring means.
    fn append(&mut self, i: usize, patches: &[f32], k: usize) -> Result<()>;
    /// Forget the last `k` patches of sequence `i` (rejected speculation).
    fn rollback(&mut self, i: usize, k: usize) -> Result<()>;
    /// Slide sequence `i`'s window so exactly `keep` patches remain.
    fn evict_to(&mut self, i: usize, keep: usize) -> Result<()>;
    /// Batched forward passes run so far (perf accounting).
    fn forwards(&self) -> usize;
}

/// Start a session on `backend`: the KV-cached implementation when
/// `cache` is [`CacheMode::On`] and the backend has one, else the
/// stateless wrapper. `history` is flat `[n_hist, patch]`, `n_hist >= 1`.
pub fn begin_session<'a>(
    backend: &'a dyn Backend,
    cache: CacheMode,
    history: &[f32],
    n_hist: usize,
) -> Result<Box<dyn DecodeSession + 'a>> {
    if cache == CacheMode::On {
        if let Some(nb) = backend.as_native() {
            return Ok(Box::new(nb.begin_cached(history, n_hist)?));
        }
    }
    Ok(Box::new(StatelessSession::new(backend, history, n_hist)?))
}

/// Batched counterpart of [`begin_session`]: one session per
/// `(history, n_hist)` task, advanced in lockstep.
pub fn begin_batch_session<'a>(
    backend: &'a dyn Backend,
    cache: CacheMode,
    tasks: &[(&[f32], usize)],
) -> Result<Box<dyn BatchDecodeSession + 'a>> {
    if cache == CacheMode::On {
        if let Some(nb) = backend.as_native() {
            return Ok(Box::new(nb.begin_cached_batch(tasks)?));
        }
    }
    Ok(Box::new(StatelessBatchSession::new(backend, tasks)?))
}

// ---------------------------------------------------------------------------
// Stateless (cache-off) sessions.
// ---------------------------------------------------------------------------

/// Cache-off session: context is a token buffer; every stale read is one
/// full `Backend::forward` over it. Means from the last forward are kept
/// and remain valid across `rollback` (causality) but not across
/// `evict_to` (the window moved under every position).
pub struct StatelessSession<'a> {
    backend: &'a dyn Backend,
    tokens: Vec<f32>,
    /// Outputs of the last forward, rows `0..valid`.
    means: Vec<f32>,
    valid: usize,
    forwards: usize,
}

impl<'a> StatelessSession<'a> {
    /// Session over `backend` primed with `history` (flat `[n_hist, patch]`).
    pub fn new(backend: &'a dyn Backend, history: &[f32], n_hist: usize) -> Result<Self> {
        let p = backend.patch();
        anyhow::ensure!(n_hist >= 1, "session needs at least one history patch");
        anyhow::ensure!(history.len() >= n_hist * p, "history too short");
        // Over-long histories keep their trailing window — the same silent
        // clamp every decode loop applied before sessions existed.
        let keep = n_hist.min(backend.max_ctx());
        Ok(StatelessSession {
            backend,
            tokens: history[(n_hist - keep) * p..n_hist * p].to_vec(),
            means: Vec::new(),
            valid: 0,
            forwards: 0,
        })
    }

    fn refresh(&mut self) -> Result<()> {
        let n = self.len();
        if self.valid < n {
            self.means = self.backend.forward(&self.tokens, n)?;
            self.valid = n;
            self.forwards += 1;
        }
        Ok(())
    }

    /// Slide the window if appending `k` patches would exceed max_ctx.
    fn room_for(&mut self, k: usize) -> Result<()> {
        let cap = self.max_ctx();
        if self.len() + k > cap {
            anyhow::ensure!(k < cap, "append of {k} patches cannot fit in max_ctx {cap}");
            self.evict_to(cap - k)?;
        }
        Ok(())
    }
}

impl DecodeSession for StatelessSession<'_> {
    fn patch(&self) -> usize {
        self.backend.patch()
    }
    fn len(&self) -> usize {
        self.tokens.len() / self.backend.patch()
    }
    fn max_ctx(&self) -> usize {
        self.backend.max_ctx()
    }
    fn context(&self) -> &[f32] {
        &self.tokens
    }

    fn tip_mean(&mut self) -> Result<Vec<f32>> {
        self.refresh()?;
        let p = self.patch();
        let n = self.len();
        Ok(self.means[(n - 1) * p..n * p].to_vec())
    }

    fn extend(&mut self, patches: &[f32], k: usize) -> Result<Vec<f32>> {
        let p = self.patch();
        anyhow::ensure!(k >= 1, "extend needs k >= 1");
        anyhow::ensure!(patches.len() >= k * p, "patch buffer too short");
        self.room_for(k)?;
        let n0 = self.len();
        anyhow::ensure!(n0 >= 1, "extend on an empty session");
        self.tokens.extend_from_slice(&patches[..k * p]);
        let n = n0 + k;
        self.means = self.backend.forward(&self.tokens, n)?;
        self.valid = n;
        self.forwards += 1;
        Ok(self.means[(n0 - 1) * p..n * p].to_vec())
    }

    fn append(&mut self, patches: &[f32], k: usize) -> Result<()> {
        let p = self.patch();
        anyhow::ensure!(patches.len() >= k * p, "patch buffer too short");
        if k == 0 {
            return Ok(());
        }
        self.room_for(k)?;
        self.tokens.extend_from_slice(&patches[..k * p]);
        // `valid` rows keep their means: earlier outputs cannot depend on
        // the appended patches (causality). The new rows are stale until
        // the next read.
        Ok(())
    }

    fn rollback(&mut self, k: usize) -> Result<()> {
        if k == 0 {
            return Ok(());
        }
        let p = self.patch();
        let n = self.len();
        anyhow::ensure!(k < n, "rollback({k}) would empty a session of {n}");
        let keep = n - k;
        self.tokens.truncate(keep * p);
        self.valid = self.valid.min(keep);
        self.means.truncate(self.valid * p);
        Ok(())
    }

    fn evict_to(&mut self, keep: usize) -> Result<()> {
        let p = self.patch();
        let n = self.len();
        anyhow::ensure!(keep >= 1 && keep <= n, "bad evict target {keep} for len {n}");
        if keep == n {
            return Ok(());
        }
        self.tokens.drain(..(n - keep) * p);
        // Every output was conditioned on the old window start.
        self.valid = 0;
        self.means.clear();
        Ok(())
    }

    fn forwards(&self) -> usize {
        self.forwards
    }
}

struct SeqBuf {
    tokens: Vec<f32>,
    means: Vec<f32>,
    valid: usize,
}

/// Cache-off lockstep sessions: stale reads over an index set become one
/// zero-padded `forward_batch` (tail padding is inert under causality),
/// exactly the execution shape of the pre-session batched decoder.
pub struct StatelessBatchSession<'a> {
    backend: &'a dyn Backend,
    seqs: Vec<SeqBuf>,
    forwards: usize,
}

impl<'a> StatelessBatchSession<'a> {
    /// One session per `(history, n_hist)` task over a shared backend.
    pub fn new(backend: &'a dyn Backend, tasks: &[(&[f32], usize)]) -> Result<Self> {
        let p = backend.patch();
        let mut seqs = Vec::with_capacity(tasks.len());
        for (hist, n_hist) in tasks {
            anyhow::ensure!(*n_hist >= 1, "session needs at least one history patch");
            anyhow::ensure!(hist.len() >= n_hist * p, "history too short");
            // Trailing-window clamp, same rule as the single-sequence path.
            let keep = (*n_hist).min(backend.max_ctx());
            seqs.push(SeqBuf {
                tokens: hist[(n_hist - keep) * p..n_hist * p].to_vec(),
                means: Vec::new(),
                valid: 0,
            });
        }
        Ok(StatelessBatchSession { backend, seqs, forwards: 0 })
    }

    /// One padded batched forward over the stale subset of `idx`.
    fn refresh(&mut self, idx: &[usize]) -> Result<()> {
        let p = self.backend.patch();
        let stale: Vec<usize> = idx
            .iter()
            .copied()
            .filter(|&i| self.seqs[i].valid * p < self.seqs[i].tokens.len())
            .collect();
        if stale.is_empty() {
            return Ok(());
        }
        let n_max = stale.iter().map(|&i| self.seqs[i].tokens.len() / p).max().unwrap();
        let mut buf = vec![0.0f32; stale.len() * n_max * p];
        for (ai, &i) in stale.iter().enumerate() {
            let t = &self.seqs[i].tokens;
            buf[ai * n_max * p..ai * n_max * p + t.len()].copy_from_slice(t);
        }
        let means = self.backend.forward_batch(&buf, stale.len(), n_max)?;
        self.forwards += 1;
        for (ai, &i) in stale.iter().enumerate() {
            let n_i = self.seqs[i].tokens.len() / p;
            self.seqs[i].means = means[ai * n_max * p..ai * n_max * p + n_i * p].to_vec();
            self.seqs[i].valid = n_i;
        }
        Ok(())
    }

    fn room_for(&mut self, i: usize, k: usize) -> Result<()> {
        let cap = self.backend.max_ctx();
        if self.len(i) + k > cap {
            anyhow::ensure!(k < cap, "append of {k} patches cannot fit in max_ctx {cap}");
            self.evict_to(i, cap - k)?;
        }
        Ok(())
    }
}

impl BatchDecodeSession for StatelessBatchSession<'_> {
    fn batch(&self) -> usize {
        self.seqs.len()
    }
    fn patch(&self) -> usize {
        self.backend.patch()
    }
    fn len(&self, i: usize) -> usize {
        self.seqs[i].tokens.len() / self.backend.patch()
    }
    fn max_ctx(&self) -> usize {
        self.backend.max_ctx()
    }

    fn tip_means(&mut self, idx: &[usize]) -> Result<Vec<f32>> {
        self.refresh(idx)?;
        let p = self.patch();
        let mut out = Vec::with_capacity(idx.len() * p);
        for &i in idx {
            let n = self.len(i);
            out.extend_from_slice(&self.seqs[i].means[(n - 1) * p..n * p]);
        }
        Ok(out)
    }

    fn extend(&mut self, idx: &[usize], patches: &[f32], k: usize) -> Result<Vec<f32>> {
        let p = self.patch();
        anyhow::ensure!(k >= 1, "extend needs k >= 1");
        anyhow::ensure!(patches.len() >= idx.len() * k * p, "patch buffer too short");
        for (ai, &i) in idx.iter().enumerate() {
            self.room_for(i, k)?;
            anyhow::ensure!(self.len(i) >= 1, "extend on an empty sequence");
            self.seqs[i].tokens.extend_from_slice(&patches[ai * k * p..(ai + 1) * k * p]);
        }
        self.refresh(idx)?;
        let mut out = Vec::with_capacity(idx.len() * (k + 1) * p);
        for &i in idx {
            let n = self.len(i);
            let n0 = n - k;
            out.extend_from_slice(&self.seqs[i].means[(n0 - 1) * p..n * p]);
        }
        Ok(out)
    }

    fn append(&mut self, i: usize, patches: &[f32], k: usize) -> Result<()> {
        let p = self.patch();
        anyhow::ensure!(patches.len() >= k * p, "patch buffer too short");
        if k == 0 {
            return Ok(());
        }
        self.room_for(i, k)?;
        self.seqs[i].tokens.extend_from_slice(&patches[..k * p]);
        Ok(())
    }

    fn rollback(&mut self, i: usize, k: usize) -> Result<()> {
        if k == 0 {
            return Ok(());
        }
        let p = self.patch();
        let n = self.len(i);
        anyhow::ensure!(k < n, "rollback({k}) would empty sequence {i} of {n}");
        let keep = n - k;
        let s = &mut self.seqs[i];
        s.tokens.truncate(keep * p);
        s.valid = s.valid.min(keep);
        s.means.truncate(s.valid * p);
        Ok(())
    }

    fn evict_to(&mut self, i: usize, keep: usize) -> Result<()> {
        let p = self.patch();
        let n = self.len(i);
        anyhow::ensure!(keep >= 1 && keep <= n, "bad evict target {keep} for len {n}");
        if keep == n {
            return Ok(());
        }
        let s = &mut self.seqs[i];
        s.tokens.drain(..(n - keep) * p);
        s.valid = 0;
        s.means.clear();
        Ok(())
    }

    fn forwards(&self) -> usize {
        self.forwards
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::AnalyticBackend;

    /// The analytic head makes session semantics directly checkable:
    /// mean(next) = a * last_patch + b.
    fn backend() -> AnalyticBackend {
        AnalyticBackend::new("t", 2, 0.5, 1.0)
    }

    #[test]
    fn tip_and_extend_follow_the_analytic_law() {
        let b = backend();
        let mut s = StatelessSession::new(&b, &[2.0, 4.0], 1).unwrap();
        assert_eq!(s.tip_mean().unwrap(), vec![2.0, 3.0]);
        // extend returns rows n0-1..n0+k-1: here positions 0 and 1.
        let rows = s.extend(&[1.0, 1.0], 1).unwrap();
        assert_eq!(rows, vec![2.0, 3.0, 1.5, 1.5]);
        assert_eq!(s.len(), 2);
        assert_eq!(s.tip_mean().unwrap(), vec![1.5, 1.5]);
    }

    #[test]
    fn rollback_restores_previous_tip_without_reforward() {
        let b = backend();
        let mut s = StatelessSession::new(&b, &[2.0, 4.0], 1).unwrap();
        let _ = s.extend(&[1.0, 1.0, 8.0, 8.0], 2).unwrap();
        let fwds = s.forwards();
        s.rollback(2).unwrap();
        assert_eq!(s.len(), 1);
        // Causality: the kept row's mean is still valid, no forward needed.
        assert_eq!(s.tip_mean().unwrap(), vec![2.0, 3.0]);
        assert_eq!(s.forwards(), fwds);
    }

    #[test]
    fn append_defers_compute() {
        let b = backend();
        let mut s = StatelessSession::new(&b, &[2.0, 4.0], 1).unwrap();
        s.append(&[6.0, 6.0], 1).unwrap();
        assert_eq!(s.forwards(), 0);
        assert_eq!(s.tip_mean().unwrap(), vec![4.0, 4.0]);
        assert_eq!(s.forwards(), 1);
    }

    #[test]
    fn rollback_refuses_to_empty() {
        let b = backend();
        let mut s = StatelessSession::new(&b, &[2.0, 4.0], 1).unwrap();
        assert!(s.rollback(1).is_err());
        s.append(&[1.0, 1.0], 1).unwrap();
        assert!(s.rollback(1).is_ok());
    }

    #[test]
    fn batch_session_matches_singles() {
        let b = backend();
        let h1 = [2.0f32, 4.0];
        let h2 = [0.0f32, 0.0, 6.0, 2.0];
        let tasks: Vec<(&[f32], usize)> = vec![(&h1, 1), (&h2, 2)];
        let mut bs = StatelessBatchSession::new(&b, &tasks).unwrap();
        let tips = bs.tip_means(&[0, 1]).unwrap();
        assert_eq!(tips, vec![2.0, 3.0, 4.0, 2.0]);
        let rows = bs.extend(&[0, 1], &[1.0, 1.0, 5.0, 5.0], 1).unwrap();
        // Per sequence: [tip_before, new_tip].
        assert_eq!(rows, vec![2.0, 3.0, 1.5, 1.5, 4.0, 2.0, 3.5, 3.5]);
        bs.rollback(0, 1).unwrap();
        assert_eq!(bs.len(0), 1);
        assert_eq!(bs.len(1), 3);
    }
}
