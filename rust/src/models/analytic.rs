//! Analytic backend: closed-form AR(1) patch heads with *no* neural net.
//!
//! mean(patch_{t+1}) = a * patch_t + b, elementwise. Because the conditional
//! law at every step is known exactly, this backend powers the statistical
//! tests of the SD variants (lossless exactness, practical TV <= alpha-bar)
//! where the NN backends would confound sampling error with model error.

use anyhow::Result;

use super::Backend;

/// Closed-form AR(1) backend: `mean(next) = a * last + b` elementwise.
#[derive(Clone, Debug)]
pub struct AnalyticBackend {
    /// Backend label for logs and stats.
    pub name: String,
    /// Values per patch.
    pub patch: usize,
    /// AR coefficient.
    pub a: f32,
    /// AR intercept.
    pub b: f32,
    /// Pretend FLOPs so cost ratios are well-defined in tests.
    pub pseudo_flops: f64,
}

impl AnalyticBackend {
    /// Head with `mean(next) = a * last + b`.
    pub fn new(name: &str, patch: usize, a: f32, b: f32) -> AnalyticBackend {
        AnalyticBackend { name: name.into(), patch, a, b, pseudo_flops: 1.0 }
    }

    /// Closed-form mean given the last patch.
    pub fn mean_next(&self, last_patch: &[f32]) -> Vec<f32> {
        last_patch.iter().map(|x| self.a * x + self.b).collect()
    }
}

impl Backend for AnalyticBackend {
    fn name(&self) -> &str {
        &self.name
    }
    fn patch(&self) -> usize {
        self.patch
    }
    fn max_ctx(&self) -> usize {
        usize::MAX
    }

    fn forward(&self, tokens: &[f32], n: usize) -> Result<Vec<f32>> {
        let p = self.patch;
        anyhow::ensure!(tokens.len() >= n * p, "tokens too short");
        let mut out = Vec::with_capacity(n * p);
        for t in 0..n {
            out.extend(self.mean_next(&tokens[t * p..(t + 1) * p]));
        }
        Ok(out)
    }

    fn flops(&self, n: usize) -> f64 {
        self.pseudo_flops * n as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ar1_means() {
        let m = AnalyticBackend::new("t", 2, 0.5, 1.0);
        let out = m.forward(&[2.0, 4.0, 0.0, 0.0], 2).unwrap();
        assert_eq!(out, vec![2.0, 3.0, 1.0, 1.0]);
    }

    #[test]
    fn causal_by_construction() {
        let m = AnalyticBackend::new("t", 1, 0.9, 0.0);
        let a = m.forward(&[1.0, 2.0, 3.0], 3).unwrap();
        let b = m.forward(&[1.0, 2.0, 99.0], 3).unwrap();
        assert_eq!(a[0], b[0]);
        assert_eq!(a[1], b[1]);
        assert_ne!(a[2], b[2]);
    }
}
