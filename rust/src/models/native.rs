//! Pure-Rust backend over [`crate::nn::NativeModel`]: the CPU reference
//! comparator and the PJRT-free test/bench path.
//!
//! This is the one backend with a real KV-cached decode session
//! ([`NativeSession`] / [`NativeBatchSession`]): `begin_cached` prefills
//! per-layer K/V ring buffers once, then every `extend` costs O(k·n·d)
//! instead of the stateless O(n²·d) re-forward. Rollback truncates the
//! buffers (causality keeps the prefix valid); window eviction re-prefills
//! the kept suffix because the learned absolute positions shift.
//!
//! Kernel-layer guarantees (see `models/README.md`):
//! * **Zero-allocation steady state** — session token/mean buffers are
//!   reserved to `max_ctx` up front and the forward arena lives inside the
//!   `KvCache`, so a steady-state `extend` heap-allocates only the
//!   trait-mandated return `Vec` (pinned by `tests/alloc_discipline.rs`).
//! * **Stacked lockstep rounds** — when every addressed sequence sits at
//!   the same length, [`NativeBatchSession::extend`] folds the whole round
//!   into ONE stacked forward ([`NativeModel::forward_cached_lockstep`]):
//!   every GEMM spans `b*k` rows instead of `b` narrow calls. Bitwise
//!   identical to the serial loop (pinned by
//!   `tests/kernel_equivalence.rs`).
//! * **Parallel batched verify** — when lengths diverge,
//!   [`NativeBatchSession::extend`] fans the per-sequence incremental
//!   forwards across the shared worker pool
//!   ([`crate::util::threadpool::global_pool`]), so a lockstep round costs
//!   max-of-sequences wall clock instead of sum. Each sequence runs the
//!   identical serial code path, so results are bitwise independent of
//!   the thread count (pinned by `tests/kernel_equivalence.rs`).
//! * **Stacked tree verify** — [`NativeSession`] overrides
//!   `DecodeSession::verify_stacked`: k branch suffixes are verified by
//!   ONE stacked target forward against the immutable shared-prefix cache
//!   ([`NativeModel::forward_cached_stacked`]), bitwise identical to the
//!   sequential extend/rollback loop (pinned by
//!   `tests/tree_equivalence.rs`).

use std::sync::Mutex;

use anyhow::Result;

use super::session::{BatchDecodeSession, DecodeSession};
use super::Backend;
use crate::nn::kernel::MAX_STACK_LANES;
use crate::nn::{ForwardScratch, KvCache, ModelDims, NativeModel, StackedLanes, Weights};
use crate::runtime::{Manifest, ModelEntry};
use crate::util::stats::Summary;
use crate::util::tensor::Tensor;
use crate::util::threadpool::{global_pool, in_worker};

/// Pure-Rust transformer backend (the KV-cached decode path and the
/// PJRT-free bench/test substrate).
pub struct NativeBackend {
    model: NativeModel,
    timings: Mutex<Summary>,
}

impl NativeBackend {
    /// Wrap a loaded [`NativeModel`].
    pub fn new(model: NativeModel) -> NativeBackend {
        NativeBackend { model, timings: Mutex::new(Summary::new()) }
    }

    /// Load from a manifest model entry (weights blob + tensor index).
    pub fn from_entry(entry: &ModelEntry) -> Result<NativeBackend> {
        let w = Weights::load(&entry.weights_file, &entry.tensor_index)?;
        Ok(NativeBackend::new(NativeModel::new(&entry.name, entry.dims, w)?))
    }

    /// Load the (target, draft) pair from the artifacts manifest.
    pub fn pair_from_manifest(m: &Manifest) -> Result<(NativeBackend, NativeBackend)> {
        Ok((Self::from_entry(&m.target)?, Self::from_entry(&m.draft)?))
    }

    /// The wrapped model's architecture dimensions.
    pub fn dims(&self) -> &ModelDims {
        &self.model.dims
    }

    /// Borrow the wrapped model (registry equivalence tests forward
    /// through it directly).
    pub fn model(&self) -> &NativeModel {
        &self.model
    }

    /// An independent backend over the same `Arc`-shared weight storage
    /// (see [`NativeModel::replicate`]): its own packed handles, its own
    /// timing summary (so the measured cost ratio c stays per-replica
    /// honest), zero float duplication. The serving replica pool builds
    /// its N model stacks with this.
    pub fn replicate(&self) -> Result<NativeBackend> {
        Ok(NativeBackend::new(self.model.replicate()?))
    }

    /// Route all forwards through the pre-kernel-layer reference
    /// implementation — the `perf_hotpath` "before" flag and the baseline
    /// of the kernel equivalence suite.
    pub fn set_reference_kernel(&mut self, on: bool) {
        self.model.set_reference(on);
    }

    /// Start a KV-cached decode session primed with `history`
    /// (flat `[n_hist, patch]`, `n_hist >= 1`). One prefill forward fills
    /// the per-layer K/V buffers and the per-position means.
    pub fn begin_cached(&self, history: &[f32], n_hist: usize) -> Result<NativeSession<'_>> {
        NativeSession::new(self, history, n_hist)
    }

    /// Batched counterpart of [`NativeBackend::begin_cached`]: one cached
    /// session per `(history, n_hist)` task, with per-sequence rollback
    /// for the lockstep decoder. Prefill forwards parallelize row-wise via
    /// `matmul_auto`; subsequent lockstep reads fan across sequences.
    pub fn begin_cached_batch(&self, tasks: &[(&[f32], usize)]) -> Result<NativeBatchSession<'_>> {
        let seqs = tasks
            .iter()
            .map(|(h, n)| NativeSession::new(self, h, *n))
            .collect::<Result<Vec<_>>>()?;
        Ok(NativeBatchSession { seqs, stack: None, stack_rows: 0 })
    }
}

/// KV-cached decode session over a [`NativeBackend`].
///
/// Holds the context tokens (needed to re-prefill after a window slide),
/// the per-layer K/V cache (which owns the forward scratch arena), and the
/// model output at *every* position — so `tip_mean` is always free and
/// `rollback` restores the previous tip without recomputation. Token and
/// mean buffers are reserved to `max_ctx` at construction: steady-state
/// appends never reallocate.
pub struct NativeSession<'a> {
    backend: &'a NativeBackend,
    cache: KvCache,
    tokens: Vec<f32>,
    means: Vec<f32>,
    forwards: usize,
    /// Per-branch K/V lanes for the stacked tree verify
    /// (`DecodeSession::verify_stacked`); empty until the first k > 1
    /// round, then reused at its high-water mark.
    lanes: StackedLanes,
}

impl<'a> NativeSession<'a> {
    fn new(backend: &'a NativeBackend, history: &[f32], n_hist: usize) -> Result<Self> {
        let p = backend.patch();
        anyhow::ensure!(n_hist >= 1, "session needs at least one history patch");
        anyhow::ensure!(history.len() >= n_hist * p, "history too short");
        // Trailing-window clamp, matching the stateless sessions.
        let keep = n_hist.min(backend.max_ctx());
        let cap = backend.max_ctx() * p;
        let mut tokens = Vec::with_capacity(cap);
        tokens.extend_from_slice(&history[(n_hist - keep) * p..n_hist * p]);
        let mut s = NativeSession {
            backend,
            cache: KvCache::new(&backend.model.dims),
            tokens,
            means: Vec::with_capacity(cap),
            forwards: 0,
            lanes: StackedLanes::new(),
        };
        Self::run_forward(
            s.backend,
            &mut s.cache,
            &mut s.means,
            &s.tokens,
            keep,
            &mut s.forwards,
        )?;
        Ok(s)
    }

    /// One incremental forward appended straight into `means` (no
    /// intermediate buffer), timed into the backend's summary so
    /// `mean_secs` (the paper's measured cost ratio c) reflects the
    /// cached regime when caching is on. Free function over disjoint
    /// fields so callers can pass `&self.tokens` alongside the `&mut`s.
    fn run_forward(
        backend: &NativeBackend,
        cache: &mut KvCache,
        means: &mut Vec<f32>,
        patches: &[f32],
        k: usize,
        forwards: &mut usize,
    ) -> Result<()> {
        let t0 = std::time::Instant::now();
        let rows = backend.model.forward_cached(cache, patches, k)?;
        means.extend_from_slice(rows);
        backend.timings.lock().unwrap().push(t0.elapsed().as_secs_f64());
        *forwards += 1;
        Ok(())
    }

    /// Slide the window if appending `k` patches would exceed max_ctx.
    fn room_for(&mut self, k: usize) -> Result<()> {
        let cap = self.max_ctx();
        if self.len() + k > cap {
            anyhow::ensure!(k < cap, "append of {k} patches cannot fit in max_ctx {cap}");
            self.evict_to(cap - k)?;
        }
        Ok(())
    }
}

impl DecodeSession for NativeSession<'_> {
    fn patch(&self) -> usize {
        self.backend.patch()
    }
    fn len(&self) -> usize {
        self.cache.len()
    }
    fn max_ctx(&self) -> usize {
        self.backend.max_ctx()
    }
    fn context(&self) -> &[f32] {
        &self.tokens
    }

    fn tip_mean(&mut self) -> Result<Vec<f32>> {
        let p = self.patch();
        let n = self.len();
        Ok(self.means[(n - 1) * p..n * p].to_vec())
    }

    fn extend(&mut self, patches: &[f32], k: usize) -> Result<Vec<f32>> {
        let p = self.patch();
        anyhow::ensure!(k >= 1, "extend needs k >= 1");
        anyhow::ensure!(patches.len() >= k * p, "patch buffer too short");
        self.room_for(k)?;
        let n0 = self.len();
        anyhow::ensure!(n0 >= 1, "extend on an empty session");
        Self::run_forward(
            self.backend,
            &mut self.cache,
            &mut self.means,
            &patches[..k * p],
            k,
            &mut self.forwards,
        )?;
        self.tokens.extend_from_slice(&patches[..k * p]);
        let n = n0 + k;
        Ok(self.means[(n0 - 1) * p..n * p].to_vec())
    }

    fn append(&mut self, patches: &[f32], k: usize) -> Result<()> {
        if k == 0 {
            return Ok(());
        }
        // Incremental compute is cheap, and keeping the means current is
        // what makes the next round's tip free.
        self.extend(patches, k).map(|_| ())
    }

    fn rollback(&mut self, k: usize) -> Result<()> {
        if k == 0 {
            return Ok(());
        }
        let p = self.patch();
        let n = self.len();
        anyhow::ensure!(k < n, "rollback({k}) would empty a session of {n}");
        let keep = n - k;
        self.cache.truncate(keep);
        self.tokens.truncate(keep * p);
        self.means.truncate(keep * p);
        Ok(())
    }

    fn evict_to(&mut self, keep: usize) -> Result<()> {
        let p = self.patch();
        let n = self.len();
        anyhow::ensure!(keep >= 1 && keep <= n, "bad evict target {keep} for len {n}");
        if keep == n {
            return Ok(());
        }
        self.tokens.drain(..(n - keep) * p);
        // Absolute positions shifted under every kept row: re-prefill.
        self.cache.reset();
        self.means.clear();
        Self::run_forward(
            self.backend,
            &mut self.cache,
            &mut self.means,
            &self.tokens,
            keep,
            &mut self.forwards,
        )?;
        Ok(())
    }

    fn forwards(&self) -> usize {
        self.forwards
    }

    fn verify_stacked(
        &mut self,
        branches: &[f32],
        b: usize,
        k: usize,
        out: &mut Vec<f32>,
    ) -> Result<bool> {
        let p = self.patch();
        anyhow::ensure!(b >= 1 && k >= 1, "verify_stacked needs b >= 1 and k >= 1");
        anyhow::ensure!(
            branches.len() == b * k * p,
            "verify_stacked: branch buffer has {} values, want b*k*patch = {}",
            branches.len(),
            b * k * p
        );
        let n0 = self.len();
        anyhow::ensure!(n0 >= 1, "verify_stacked on an empty session");
        // Fall back to the sequential per-branch path (Ok(false)) when the
        // stacked kernel cannot apply: reference-kernel mode (the wall's
        // baseline), more branches than lanes, or a round the caller
        // should have made room for first.
        if self.backend.model.reference_kernel()
            || b > MAX_STACK_LANES
            || n0 + k > self.max_ctx()
        {
            return Ok(false);
        }
        let t0 = std::time::Instant::now();
        let rows = self
            .backend
            .model
            .forward_cached_stacked(&self.cache, &mut self.lanes, branches, b, k)?;
        // Row 0 of every branch's (k+1)-row result is the shared tip mean —
        // already computed by the forward that produced position n0-1's
        // output, exactly as the sequential extend() returns it.
        out.clear();
        out.reserve(b * (k + 1) * p);
        let tip = &self.means[(n0 - 1) * p..n0 * p];
        for lane in 0..b {
            out.extend_from_slice(tip);
            out.extend_from_slice(&rows[lane * k * p..(lane + 1) * k * p]);
        }
        self.backend.timings.lock().unwrap().push(t0.elapsed().as_secs_f64());
        self.forwards += 1;
        Ok(true)
    }
}

/// Per-sequence cached sessions advanced in lockstep. Batched reads fan
/// the per-sequence incremental forwards — each O(k·n_i·d) — across the
/// shared worker pool, so a verify round costs the *max* of its sequences
/// instead of their sum (the serving-throughput lever of the batched
/// decoder). Writes (append/rollback/evict) stay per-sequence because
/// acceptance lengths diverge.
pub struct NativeBatchSession<'a> {
    seqs: Vec<NativeSession<'a>>,
    /// Reusable arena for the aligned-lengths stacked lockstep path; built
    /// lazily at the first aligned round and grown to a high-water row
    /// count, so steady-state stacked rounds allocate nothing beyond the
    /// trait-mandated return `Vec`s.
    stack: Option<ForwardScratch>,
    stack_rows: usize,
}

// The batched-verify fan-out smuggles `&mut NativeSession` across worker
// threads as a raw address, which erases the compiler's Send/Sync
// checking — pin the invariants it relies on at compile time so a future
// non-thread-safe field (RefCell, Rc, …) fails the build instead of
// becoming a silent data race.
const _: () = {
    const fn assert_sync<T: Sync>() {}
    const fn assert_send<T: Send>() {}
    assert_sync::<NativeBackend>();
    assert_send::<NativeSession<'static>>();
};

/// Minimum per-sequence flops for the k = 1 fan-out to beat its dispatch
/// cost (job box + channel hops + mutex queue pickup ≈ a few µs).
const PAR_MIN_SEQ_FLOPS: usize = 128 * 1024;

impl NativeBatchSession<'_> {
    /// Fan `extend` over the pool when it can help: at least two
    /// sequences, a real pool, strictly increasing indices (distinct
    /// sessions — the engine's active sets are sorted), not already on a
    /// pool worker (a nested map_wait would deadlock), and enough
    /// per-sequence work to amortize dispatch. Verify reads (k ≥ 2
    /// target rows) always qualify; the γ per-round k = 1 draft proposal
    /// steps only fan out when one incremental forward is heavy enough —
    /// a tiny draft's microsecond step stays on the serial loop.
    fn parallel_ok(&self, idx: &[usize], k: usize) -> bool {
        if idx.len() < 2
            || in_worker()
            || global_pool().size() <= 1
            || !idx.windows(2).all(|w| w[0] < w[1])
        {
            return false;
        }
        if k >= 2 {
            return true;
        }
        let m = self.seqs[idx[0]].backend.dims();
        let n = idx.iter().map(|&i| self.seqs[i].len()).max().unwrap_or(0);
        let per_seq =
            k * m.n_layers * (m.d_model * (4 * m.d_model + 3 * m.d_ff) + n * m.d_model);
        per_seq >= PAR_MIN_SEQ_FLOPS
    }

    /// Aligned-lengths fast path: when every addressed sequence sits at
    /// the same length, advance them all with ONE stacked forward
    /// ([`NativeModel::forward_cached_lockstep`]) — every GEMM in the
    /// round spans `b*k` rows instead of `b` separate `k`-row calls.
    /// Returns `Ok(None)` (fall through to the pool fan-out / serial
    /// loop) when lengths diverge, fewer than two sequences are
    /// addressed, or the reference kernel is active. Bitwise identical to
    /// the serial path (pinned by `tests/kernel_equivalence.rs`).
    fn try_extend_stacked(
        &mut self,
        idx: &[usize],
        patches: &[f32],
        k: usize,
    ) -> Result<Option<Vec<f32>>> {
        let p = self.patch();
        let b = idx.len();
        if b < 2
            || !idx.windows(2).all(|w| w[0] < w[1])
            || self.seqs[idx[0]].backend.model.reference_kernel()
        {
            return Ok(None);
        }
        let n_pre = self.seqs[idx[0]].len();
        if idx.iter().any(|&i| self.seqs[i].len() != n_pre) {
            return Ok(None);
        }
        // Same length + same max_ctx => the window slide (if any) is
        // identical per sequence, so lengths stay aligned afterwards.
        for &i in idx {
            self.seqs[i].room_for(k)?;
        }
        let n0 = self.seqs[idx[0]].len();
        anyhow::ensure!(n0 >= 1, "extend on an empty session");
        let rows = b * k;
        let backend = self.seqs[idx[0]].backend;
        if self.stack.is_none() || self.stack_rows < rows {
            self.stack_rows = self.stack_rows.max(rows);
            self.stack = Some(ForwardScratch::for_prefill(backend.dims(), self.stack_rows));
        }
        // Disjoint `&mut` per cache via split_at_mut walks (idx is
        // strictly increasing — checked above).
        let mut refs: Vec<&mut KvCache> = Vec::with_capacity(b);
        let mut rest: &mut [NativeSession] = &mut self.seqs;
        let mut prev = 0usize;
        for &i in idx {
            let (_, tail) = rest.split_at_mut(i - prev);
            let (one, tail) = tail.split_at_mut(1);
            refs.push(&mut one[0].cache);
            rest = tail;
            prev = i + 1;
        }
        let t0 = std::time::Instant::now();
        let scratch = self.stack.as_mut().expect("stacked scratch sized above");
        let rows_out = backend.model.forward_cached_lockstep(
            &mut refs,
            scratch,
            &patches[..rows * p],
            k,
        )?;
        // One fused forward, one timing record.
        backend.timings.lock().unwrap().push(t0.elapsed().as_secs_f64());
        let n = n0 + k;
        let mut out = Vec::with_capacity(b * (k + 1) * p);
        for (ai, &i) in idx.iter().enumerate() {
            let seq = &mut self.seqs[i];
            seq.means.extend_from_slice(&rows_out[ai * k * p..(ai + 1) * k * p]);
            seq.tokens.extend_from_slice(&patches[ai * k * p..(ai + 1) * k * p]);
            seq.forwards += 1;
            out.extend_from_slice(&seq.means[(n0 - 1) * p..n * p]);
        }
        Ok(Some(out))
    }
}

impl BatchDecodeSession for NativeBatchSession<'_> {
    fn batch(&self) -> usize {
        self.seqs.len()
    }
    fn patch(&self) -> usize {
        self.seqs[0].patch()
    }
    fn len(&self, i: usize) -> usize {
        self.seqs[i].len()
    }
    fn max_ctx(&self) -> usize {
        self.seqs[0].max_ctx()
    }

    fn tip_means(&mut self, idx: &[usize]) -> Result<Vec<f32>> {
        let p = self.patch();
        let mut out = Vec::with_capacity(idx.len() * p);
        for &i in idx {
            out.extend_from_slice(&self.seqs[i].tip_mean()?);
        }
        Ok(out)
    }

    fn extend(&mut self, idx: &[usize], patches: &[f32], k: usize) -> Result<Vec<f32>> {
        let p = self.patch();
        anyhow::ensure!(patches.len() >= idx.len() * k * p, "patch buffer too short");
        anyhow::ensure!(idx.iter().all(|&i| i < self.seqs.len()), "sequence index out of range");
        // Aligned lengths: one stacked forward for the whole round.
        if let Some(out) = self.try_extend_stacked(idx, patches, k)? {
            return Ok(out);
        }
        if !self.parallel_ok(idx, k) {
            let mut out = Vec::with_capacity(idx.len() * (k + 1) * p);
            for (ai, &i) in idx.iter().enumerate() {
                out.extend(self.seqs[i].extend(&patches[ai * k * p..(ai + 1) * k * p], k)?);
            }
            return Ok(out);
        }
        // Smuggle the borrows as addresses: the pool's Job type is
        // 'static, but map_wait joins every job before returning, so the
        // borrows strictly outlive all worker accesses. `idx` is strictly
        // increasing (checked above), so each job gets a distinct
        // `&mut NativeSession` and a disjoint slice of `patches`.
        let seqs_addr = self.seqs.as_mut_ptr() as usize;
        let patches_addr = patches.as_ptr() as usize;
        let patches_len = patches.len();
        let idx_owned: Vec<usize> = idx.to_vec();
        let results = global_pool().map_wait(idx_owned.len(), move |ai| {
            let i = idx_owned[ai];
            // SAFETY: distinct i per job (strictly increasing idx), joined
            // before the caller's &mut self ends; the session type's
            // borrow of the backend is Sync (Mutex-guarded timings).
            let sess: &mut NativeSession =
                unsafe { &mut *(seqs_addr as *mut NativeSession).add(i) };
            let patches: &[f32] =
                unsafe { std::slice::from_raw_parts(patches_addr as *const f32, patches_len) };
            sess.extend(&patches[ai * k * p..(ai + 1) * k * p], k)
        })?;
        let mut out = Vec::with_capacity(idx.len() * (k + 1) * p);
        for rows in results {
            out.extend(rows?);
        }
        Ok(out)
    }

    fn append(&mut self, i: usize, patches: &[f32], k: usize) -> Result<()> {
        self.seqs[i].append(patches, k)
    }

    fn rollback(&mut self, i: usize, k: usize) -> Result<()> {
        self.seqs[i].rollback(k)
    }

    fn evict_to(&mut self, i: usize, keep: usize) -> Result<()> {
        self.seqs[i].evict_to(keep)
    }

    fn forwards(&self) -> usize {
        self.seqs.iter().map(|s| s.forwards()).sum()
    }
}

impl Backend for NativeBackend {
    fn name(&self) -> &str {
        &self.model.name
    }
    fn patch(&self) -> usize {
        self.model.dims.patch
    }
    fn max_ctx(&self) -> usize {
        self.model.dims.n_ctx
    }

    fn forward(&self, tokens: &[f32], n: usize) -> Result<Vec<f32>> {
        let p = self.patch();
        anyhow::ensure!(tokens.len() >= n * p, "tokens too short");
        let t0 = std::time::Instant::now();
        let t = Tensor::from_vec(&[1, n, p], tokens[..n * p].to_vec());
        let out = self.model.forward(&t)?;
        self.timings.lock().unwrap().push(t0.elapsed().as_secs_f64());
        Ok(out.data.into_vec())
    }

    fn forward_batch(&self, tokens: &[f32], b: usize, n: usize) -> Result<Vec<f32>> {
        let p = self.patch();
        anyhow::ensure!(tokens.len() == b * n * p, "bad batch buffer");
        let t = Tensor::from_vec(&[b, n, p], tokens.to_vec());
        Ok(self.model.forward(&t)?.data.into_vec())
    }

    fn mean_secs(&self) -> f64 {
        let t = self.timings.lock().unwrap();
        if t.n == 0 {
            f64::NAN
        } else {
            t.mean()
        }
    }

    fn flops(&self, n: usize) -> f64 {
        let d = &self.model.dims;
        let per_tok = 2.0
            * (d.patch * d.d_model
                + 4 * d.d_model * d.d_model * d.n_layers
                + 3 * d.d_model * d.d_ff * d.n_layers
                + d.d_model * d.patch) as f64;
        let attn = (4 * n * n * d.d_model * d.n_layers) as f64;
        n as f64 * per_tok + attn
    }

    fn as_native(&self) -> Option<&NativeBackend> {
        Some(self)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::model::tiny_model;

    #[test]
    fn backend_forward_and_timing() {
        let b = NativeBackend::new(tiny_model(1));
        let toks = vec![0.1f32; 8 * 4];
        let out = b.forward(&toks, 8).unwrap();
        assert_eq!(out.len(), 8 * 4);
        assert!(b.mean_secs() > 0.0);
        assert!(b.flops(8) > 0.0);
    }

    #[test]
    fn default_batch_matches_loop() {
        let b = NativeBackend::new(tiny_model(2));
        let toks: Vec<f32> = (0..2 * 8 * 4).map(|i| (i as f32 * 0.1).sin()).collect();
        let batched = b.forward_batch(&toks, 2, 8).unwrap();
        let first = b.forward(&toks[..8 * 4], 8).unwrap();
        for i in 0..8 * 4 {
            assert!((batched[i] - first[i]).abs() < 1e-5);
        }
    }

    #[test]
    fn cached_session_matches_stateless_forward() {
        let b = NativeBackend::new(tiny_model(3));
        let toks: Vec<f32> = (0..6 * 4).map(|i| (i as f32 * 0.17).sin()).collect();
        let mut sess = b.begin_cached(&toks[..3 * 4], 3).unwrap();
        let rows = sess.extend(&toks[3 * 4..], 3).unwrap();
        let full = b.forward(&toks, 6).unwrap();
        // rows = outputs at positions 2..=5.
        for i in 0..4 * 4 {
            assert!(
                (rows[i] - full[2 * 4 + i]).abs() < 1e-5,
                "cached {} vs stateless {}",
                rows[i],
                full[2 * 4 + i]
            );
        }
        let tip = sess.tip_mean().unwrap();
        for i in 0..4 {
            assert!((tip[i] - full[5 * 4 + i]).abs() < 1e-5);
        }
    }

    #[test]
    fn cached_session_eviction_matches_sliding_window() {
        // Appending past max_ctx must equal the stateless window rule:
        // forward over the last max_ctx patches.
        let b = NativeBackend::new(tiny_model(4));
        let toks: Vec<f32> = (0..12 * 4).map(|i| (i as f32 * 0.13).cos()).collect();
        let mut sess = b.begin_cached(&toks[..8 * 4], 8).unwrap();
        sess.append(&toks[8 * 4..9 * 4], 1).unwrap(); // slides to keep 7, appends 1
        assert_eq!(sess.len(), 8);
        let window = &toks[1 * 4..9 * 4];
        let full = b.forward(window, 8).unwrap();
        let tip = sess.tip_mean().unwrap();
        for i in 0..4 {
            assert!((tip[i] - full[7 * 4 + i]).abs() < 1e-5);
        }
    }

    #[test]
    fn session_buffers_never_reallocate_in_steady_state() {
        // tokens/means are reserved to max_ctx·patch up front; pointer
        // stability across extends/rollbacks is the cheap proxy for the
        // zero-reallocation claim (the counting-allocator test is the
        // strict one).
        let b = NativeBackend::new(tiny_model(5));
        let toks: Vec<f32> = (0..8 * 4).map(|i| (i as f32 * 0.19).sin()).collect();
        let mut sess = b.begin_cached(&toks[..2 * 4], 2).unwrap();
        let tok_ptr = sess.tokens.as_ptr();
        let mean_ptr = sess.means.as_ptr();
        for step in 0..30 {
            let start = (step % 6) * 4;
            sess.extend(&toks[start..start + 4], 1).unwrap();
            if sess.len() > 2 {
                sess.rollback(1).unwrap();
            }
        }
        assert_eq!(tok_ptr, sess.tokens.as_ptr(), "token buffer reallocated");
        assert_eq!(mean_ptr, sess.means.as_ptr(), "means buffer reallocated");
    }

    #[test]
    fn replicate_shares_storage_and_matches_bitwise() {
        let b = NativeBackend::new(tiny_model(7));
        let r = b.replicate().unwrap();
        let toks: Vec<f32> = (0..6 * 4).map(|i| (i as f32 * 0.11).sin()).collect();
        let a = b.forward(&toks, 6).unwrap();
        let c = r.forward(&toks, 6).unwrap();
        // Same floats behind both stacks => bitwise identical outputs.
        for (x, y) in a.iter().zip(&c) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
        // Independent timing summaries: the replica's c measurement must
        // not fold into the original's.
        assert!(b.mean_secs() > 0.0);
        let fresh = NativeBackend::new(tiny_model(7));
        let rep = fresh.replicate().unwrap();
        assert!(fresh.mean_secs().is_nan());
        let _ = rep.forward(&toks, 6).unwrap();
        assert!(fresh.mean_secs().is_nan(), "replica timings leaked into source");
        assert!(rep.mean_secs() > 0.0);
    }

    #[test]
    fn batched_stacked_extend_matches_serial_singles() {
        // Equal-length histories: the stacked lockstep path engages and
        // must reproduce solo sessions bit for bit, advancing every cache.
        let b = NativeBackend::new(tiny_model(9));
        let mk = |seed: u64, n: usize| -> Vec<f32> {
            (0..n * 4).map(|i| ((i as f32 + seed as f32) * 0.29).sin()).collect()
        };
        let h1 = mk(1, 4);
        let h2 = mk(2, 4);
        let h3 = mk(3, 4);
        let tasks: Vec<(&[f32], usize)> = vec![(&h1, 4), (&h2, 4), (&h3, 4)];
        let mut bs = b.begin_cached_batch(&tasks).unwrap();
        let flat = mk(9, 6); // 3 sequences x 2 patches
        let batch_rows = bs.extend(&[0, 1, 2], &flat, 2).unwrap();
        assert_eq!(bs.len(0), 6, "stacked lockstep must advance the caches");
        for (ai, h) in [&h1, &h2, &h3].iter().enumerate() {
            let mut solo = b.begin_cached(h, 4).unwrap();
            let rows = solo.extend(&flat[ai * 2 * 4..(ai + 1) * 2 * 4], 2).unwrap();
            let got = &batch_rows[ai * 3 * 4..(ai + 1) * 3 * 4];
            for (x, y) in rows.iter().zip(got) {
                assert_eq!(x.to_bits(), y.to_bits(), "sequence {ai} diverged under stacked lockstep");
            }
        }
    }

    #[test]
    fn verify_stacked_matches_sequential_extend_rollback() {
        let b = NativeBackend::new(tiny_model(10));
        let toks: Vec<f32> = (0..4 * 4).map(|i| (i as f32 * 0.21).sin()).collect();
        let mut sess = b.begin_cached(&toks, 4).unwrap();
        let branches: Vec<f32> = (0..3 * 2 * 4).map(|i| (i as f32 * 0.15).cos()).collect();
        let mut out = Vec::new();
        let used = sess.verify_stacked(&branches, 3, 2, &mut out).unwrap();
        assert!(used, "kernel-layer session must take the stacked path");
        assert_eq!(sess.len(), 4, "stacked verify must not advance the session");
        assert_eq!(out.len(), 3 * 3 * 4, "want b * (k+1) * patch rows");
        for lane in 0..3 {
            let rows = sess.extend(&branches[lane * 8..(lane + 1) * 8], 2).unwrap();
            sess.rollback(2).unwrap();
            let got = &out[lane * 12..(lane + 1) * 12];
            for (x, y) in rows.iter().zip(got) {
                assert_eq!(x.to_bits(), y.to_bits(), "lane {lane} diverged from extend/rollback");
            }
        }
        // The reference kernel declines (the equivalence wall's baseline),
        // as does a round that would overflow the context window.
        let mut rb = NativeBackend::new(tiny_model(10));
        rb.set_reference_kernel(true);
        let mut rsess = rb.begin_cached(&toks, 4).unwrap();
        assert!(!rsess.verify_stacked(&branches, 3, 2, &mut out).unwrap());
        let wide = vec![0.1f32; 2 * 5 * 4];
        assert!(!sess.verify_stacked(&wide, 2, 5, &mut out).unwrap(), "4 + 5 > n_ctx 8");
    }

    #[test]
    fn batched_parallel_extend_matches_serial_singles() {
        // The pool fan-out must reproduce the single-session path exactly
        // (same serial kernel per sequence → bitwise equal).
        let b = NativeBackend::new(tiny_model(6));
        let mk = |seed: u64, n: usize| -> Vec<f32> {
            (0..n * 4).map(|i| ((i as f32 + seed as f32) * 0.23).sin()).collect()
        };
        let h1 = mk(1, 3);
        let h2 = mk(2, 5);
        let h3 = mk(3, 2);
        let tasks: Vec<(&[f32], usize)> = vec![(&h1, 3), (&h2, 5), (&h3, 2)];
        let mut bs = b.begin_cached_batch(&tasks).unwrap();
        let ext = mk(9, 2);
        let mut flat = Vec::new();
        for _ in 0..3 {
            flat.extend_from_slice(&ext);
        }
        let batch_rows = bs.extend(&[0, 1, 2], &flat, 2).unwrap();
        for (ai, (h, n)) in [(&h1, 3usize), (&h2, 5), (&h3, 2)].iter().enumerate() {
            let mut solo = b.begin_cached(h, *n).unwrap();
            let rows = solo.extend(&ext, 2).unwrap();
            let got = &batch_rows[ai * 3 * 4..(ai + 1) * 3 * 4];
            assert_eq!(rows.as_slice(), got, "sequence {ai} diverged under parallel verify");
        }
    }
}
