//! Pure-Rust backend over [`crate::nn::NativeModel`]: the CPU reference
//! comparator and the PJRT-free test/bench path.

use std::cell::RefCell;

use anyhow::Result;

use super::Backend;
use crate::nn::{ModelDims, NativeModel, Weights};
use crate::runtime::{Manifest, ModelEntry};
use crate::util::stats::Summary;
use crate::util::tensor::Tensor;

pub struct NativeBackend {
    model: NativeModel,
    timings: RefCell<Summary>,
}

impl NativeBackend {
    pub fn new(model: NativeModel) -> NativeBackend {
        NativeBackend { model, timings: RefCell::new(Summary::new()) }
    }

    /// Load from a manifest model entry (weights blob + tensor index).
    pub fn from_entry(entry: &ModelEntry) -> Result<NativeBackend> {
        let w = Weights::load(&entry.weights_file, &entry.tensor_index)?;
        Ok(NativeBackend::new(NativeModel::new(&entry.name, entry.dims, w)))
    }

    /// Load the (target, draft) pair from the artifacts manifest.
    pub fn pair_from_manifest(m: &Manifest) -> Result<(NativeBackend, NativeBackend)> {
        Ok((Self::from_entry(&m.target)?, Self::from_entry(&m.draft)?))
    }

    pub fn dims(&self) -> &ModelDims {
        &self.model.dims
    }
}

impl Backend for NativeBackend {
    fn name(&self) -> &str {
        &self.model.name
    }
    fn patch(&self) -> usize {
        self.model.dims.patch
    }
    fn max_ctx(&self) -> usize {
        self.model.dims.n_ctx
    }

    fn forward(&self, tokens: &[f32], n: usize) -> Result<Vec<f32>> {
        let p = self.patch();
        anyhow::ensure!(tokens.len() >= n * p, "tokens too short");
        let t0 = std::time::Instant::now();
        let t = Tensor::from_vec(&[1, n, p], tokens[..n * p].to_vec());
        let out = self.model.forward(&t)?;
        self.timings.borrow_mut().push(t0.elapsed().as_secs_f64());
        Ok(out.data)
    }

    fn forward_batch(&self, tokens: &[f32], b: usize, n: usize) -> Result<Vec<f32>> {
        let p = self.patch();
        anyhow::ensure!(tokens.len() == b * n * p, "bad batch buffer");
        let t = Tensor::from_vec(&[b, n, p], tokens.to_vec());
        Ok(self.model.forward(&t)?.data)
    }

    fn mean_secs(&self) -> f64 {
        let t = self.timings.borrow();
        if t.n == 0 {
            f64::NAN
        } else {
            t.mean()
        }
    }

    fn flops(&self, n: usize) -> f64 {
        let d = &self.model.dims;
        let per_tok = 2.0
            * (d.patch * d.d_model
                + 4 * d.d_model * d.d_model * d.n_layers
                + 3 * d.d_model * d.d_ff * d.n_layers
                + d.d_model * d.patch) as f64;
        let attn = (4 * n * n * d.d_model * d.n_layers) as f64;
        n as f64 * per_tok + attn
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::model::tiny_model;

    #[test]
    fn backend_forward_and_timing() {
        let b = NativeBackend::new(tiny_model(1));
        let toks = vec![0.1f32; 8 * 4];
        let out = b.forward(&toks, 8).unwrap();
        assert_eq!(out.len(), 8 * 4);
        assert!(b.mean_secs() > 0.0);
        assert!(b.flops(8) > 0.0);
    }

    #[test]
    fn default_batch_matches_loop() {
        let b = NativeBackend::new(tiny_model(2));
        let toks: Vec<f32> = (0..2 * 8 * 4).map(|i| (i as f32 * 0.1).sin()).collect();
        let batched = b.forward_batch(&toks, 2, 8).unwrap();
        let first = b.forward(&toks[..8 * 4], 8).unwrap();
        for i in 0..8 * 4 {
            assert!((batched[i] - first[i]).abs() < 1e-5);
        }
    }
}
