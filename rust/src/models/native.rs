//! Pure-Rust backend over [`crate::nn::NativeModel`]: the CPU reference
//! comparator and the PJRT-free test/bench path.
//!
//! This is the one backend with a real KV-cached decode session
//! ([`NativeSession`] / [`NativeBatchSession`]): `begin_cached` prefills
//! per-layer K/V ring buffers once, then every `extend` costs O(k·n·d)
//! instead of the stateless O(n²·d) re-forward. Rollback truncates the
//! buffers (causality keeps the prefix valid); window eviction re-prefills
//! the kept suffix because the learned absolute positions shift.

use std::cell::RefCell;

use anyhow::Result;

use super::session::{BatchDecodeSession, DecodeSession};
use super::Backend;
use crate::nn::{KvCache, ModelDims, NativeModel, Weights};
use crate::runtime::{Manifest, ModelEntry};
use crate::util::stats::Summary;
use crate::util::tensor::Tensor;

pub struct NativeBackend {
    model: NativeModel,
    timings: RefCell<Summary>,
}

impl NativeBackend {
    pub fn new(model: NativeModel) -> NativeBackend {
        NativeBackend { model, timings: RefCell::new(Summary::new()) }
    }

    /// Load from a manifest model entry (weights blob + tensor index).
    pub fn from_entry(entry: &ModelEntry) -> Result<NativeBackend> {
        let w = Weights::load(&entry.weights_file, &entry.tensor_index)?;
        Ok(NativeBackend::new(NativeModel::new(&entry.name, entry.dims, w)))
    }

    /// Load the (target, draft) pair from the artifacts manifest.
    pub fn pair_from_manifest(m: &Manifest) -> Result<(NativeBackend, NativeBackend)> {
        Ok((Self::from_entry(&m.target)?, Self::from_entry(&m.draft)?))
    }

    pub fn dims(&self) -> &ModelDims {
        &self.model.dims
    }

    /// Start a KV-cached decode session primed with `history`
    /// (flat `[n_hist, patch]`, `n_hist >= 1`). One prefill forward fills
    /// the per-layer K/V buffers and the per-position means.
    pub fn begin_cached(&self, history: &[f32], n_hist: usize) -> Result<NativeSession<'_>> {
        NativeSession::new(self, history, n_hist)
    }

    /// Batched counterpart of [`NativeBackend::begin_cached`]: one cached
    /// session per `(history, n_hist)` task, with per-sequence rollback
    /// for the lockstep decoder.
    pub fn begin_cached_batch(&self, tasks: &[(&[f32], usize)]) -> Result<NativeBatchSession<'_>> {
        let seqs = tasks
            .iter()
            .map(|(h, n)| NativeSession::new(self, h, *n))
            .collect::<Result<Vec<_>>>()?;
        Ok(NativeBatchSession { seqs })
    }
}

/// KV-cached decode session over a [`NativeBackend`].
///
/// Holds the context tokens (needed to re-prefill after a window slide),
/// the per-layer K/V cache, and the model output at *every* position —
/// so `tip_mean` is always free and `rollback` restores the previous tip
/// without recomputation.
pub struct NativeSession<'a> {
    backend: &'a NativeBackend,
    cache: KvCache,
    tokens: Vec<f32>,
    means: Vec<f32>,
    forwards: usize,
}

impl<'a> NativeSession<'a> {
    fn new(backend: &'a NativeBackend, history: &[f32], n_hist: usize) -> Result<Self> {
        let p = backend.patch();
        anyhow::ensure!(n_hist >= 1, "session needs at least one history patch");
        anyhow::ensure!(history.len() >= n_hist * p, "history too short");
        // Trailing-window clamp, matching the stateless sessions.
        let keep = n_hist.min(backend.max_ctx());
        let mut s = NativeSession {
            backend,
            cache: KvCache::new(&backend.model.dims),
            tokens: history[(n_hist - keep) * p..n_hist * p].to_vec(),
            means: Vec::new(),
            forwards: 0,
        };
        let toks = s.tokens.clone();
        s.means = s.run_cached_timed(&toks, keep)?;
        Ok(s)
    }

    /// One incremental forward, timed into the backend's summary so
    /// `mean_secs` (the paper's measured cost ratio c) reflects the
    /// cached regime when caching is on.
    fn run_cached_timed(&mut self, patches: &[f32], k: usize) -> Result<Vec<f32>> {
        let t0 = std::time::Instant::now();
        let out = self.backend.model.forward_cached(&mut self.cache, patches, k)?;
        self.backend.timings.borrow_mut().push(t0.elapsed().as_secs_f64());
        self.forwards += 1;
        Ok(out)
    }

    /// Slide the window if appending `k` patches would exceed max_ctx.
    fn room_for(&mut self, k: usize) -> Result<()> {
        let cap = self.max_ctx();
        if self.len() + k > cap {
            anyhow::ensure!(k < cap, "append of {k} patches cannot fit in max_ctx {cap}");
            self.evict_to(cap - k)?;
        }
        Ok(())
    }
}

impl DecodeSession for NativeSession<'_> {
    fn patch(&self) -> usize {
        self.backend.patch()
    }
    fn len(&self) -> usize {
        self.cache.len()
    }
    fn max_ctx(&self) -> usize {
        self.backend.max_ctx()
    }
    fn context(&self) -> &[f32] {
        &self.tokens
    }

    fn tip_mean(&mut self) -> Result<Vec<f32>> {
        let p = self.patch();
        let n = self.len();
        Ok(self.means[(n - 1) * p..n * p].to_vec())
    }

    fn extend(&mut self, patches: &[f32], k: usize) -> Result<Vec<f32>> {
        let p = self.patch();
        anyhow::ensure!(k >= 1, "extend needs k >= 1");
        anyhow::ensure!(patches.len() >= k * p, "patch buffer too short");
        self.room_for(k)?;
        let n0 = self.len();
        anyhow::ensure!(n0 >= 1, "extend on an empty session");
        let rows = self.run_cached_timed(&patches[..k * p], k)?;
        self.tokens.extend_from_slice(&patches[..k * p]);
        self.means.extend_from_slice(&rows);
        let n = n0 + k;
        Ok(self.means[(n0 - 1) * p..n * p].to_vec())
    }

    fn append(&mut self, patches: &[f32], k: usize) -> Result<()> {
        if k == 0 {
            return Ok(());
        }
        // Incremental compute is cheap, and keeping the means current is
        // what makes the next round's tip free.
        self.extend(patches, k).map(|_| ())
    }

    fn rollback(&mut self, k: usize) -> Result<()> {
        if k == 0 {
            return Ok(());
        }
        let p = self.patch();
        let n = self.len();
        anyhow::ensure!(k < n, "rollback({k}) would empty a session of {n}");
        let keep = n - k;
        self.cache.truncate(keep);
        self.tokens.truncate(keep * p);
        self.means.truncate(keep * p);
        Ok(())
    }

    fn evict_to(&mut self, keep: usize) -> Result<()> {
        let p = self.patch();
        let n = self.len();
        anyhow::ensure!(keep >= 1 && keep <= n, "bad evict target {keep} for len {n}");
        if keep == n {
            return Ok(());
        }
        self.tokens.drain(..(n - keep) * p);
        // Absolute positions shifted under every kept row: re-prefill.
        self.cache.reset();
        let toks = self.tokens.clone();
        self.means = self.run_cached_timed(&toks, keep)?;
        Ok(())
    }

    fn forwards(&self) -> usize {
        self.forwards
    }
}

/// Per-sequence cached sessions advanced in lockstep. Reads loop over the
/// index set with incremental forwards — each O(k·n_i·d), which already
/// beats the padded O(n_max²·d) batched re-forward by a wide margin;
/// fusing the per-sequence incremental attention into one batched kernel
/// is future work (see models/README).
pub struct NativeBatchSession<'a> {
    seqs: Vec<NativeSession<'a>>,
}

impl BatchDecodeSession for NativeBatchSession<'_> {
    fn batch(&self) -> usize {
        self.seqs.len()
    }
    fn patch(&self) -> usize {
        self.seqs[0].patch()
    }
    fn len(&self, i: usize) -> usize {
        self.seqs[i].len()
    }
    fn max_ctx(&self) -> usize {
        self.seqs[0].max_ctx()
    }

    fn tip_means(&mut self, idx: &[usize]) -> Result<Vec<f32>> {
        let p = self.patch();
        let mut out = Vec::with_capacity(idx.len() * p);
        for &i in idx {
            out.extend_from_slice(&self.seqs[i].tip_mean()?);
        }
        Ok(out)
    }

    fn extend(&mut self, idx: &[usize], patches: &[f32], k: usize) -> Result<Vec<f32>> {
        let p = self.patch();
        anyhow::ensure!(patches.len() >= idx.len() * k * p, "patch buffer too short");
        let mut out = Vec::with_capacity(idx.len() * (k + 1) * p);
        for (ai, &i) in idx.iter().enumerate() {
            out.extend(self.seqs[i].extend(&patches[ai * k * p..(ai + 1) * k * p], k)?);
        }
        Ok(out)
    }

    fn append(&mut self, i: usize, patches: &[f32], k: usize) -> Result<()> {
        self.seqs[i].append(patches, k)
    }

    fn rollback(&mut self, i: usize, k: usize) -> Result<()> {
        self.seqs[i].rollback(k)
    }

    fn evict_to(&mut self, i: usize, keep: usize) -> Result<()> {
        self.seqs[i].evict_to(keep)
    }

    fn forwards(&self) -> usize {
        self.seqs.iter().map(|s| s.forwards()).sum()
    }
}

impl Backend for NativeBackend {
    fn name(&self) -> &str {
        &self.model.name
    }
    fn patch(&self) -> usize {
        self.model.dims.patch
    }
    fn max_ctx(&self) -> usize {
        self.model.dims.n_ctx
    }

    fn forward(&self, tokens: &[f32], n: usize) -> Result<Vec<f32>> {
        let p = self.patch();
        anyhow::ensure!(tokens.len() >= n * p, "tokens too short");
        let t0 = std::time::Instant::now();
        let t = Tensor::from_vec(&[1, n, p], tokens[..n * p].to_vec());
        let out = self.model.forward(&t)?;
        self.timings.borrow_mut().push(t0.elapsed().as_secs_f64());
        Ok(out.data)
    }

    fn forward_batch(&self, tokens: &[f32], b: usize, n: usize) -> Result<Vec<f32>> {
        let p = self.patch();
        anyhow::ensure!(tokens.len() == b * n * p, "bad batch buffer");
        let t = Tensor::from_vec(&[b, n, p], tokens.to_vec());
        Ok(self.model.forward(&t)?.data)
    }

    fn mean_secs(&self) -> f64 {
        let t = self.timings.borrow();
        if t.n == 0 {
            f64::NAN
        } else {
            t.mean()
        }
    }

    fn flops(&self, n: usize) -> f64 {
        let d = &self.model.dims;
        let per_tok = 2.0
            * (d.patch * d.d_model
                + 4 * d.d_model * d.d_model * d.n_layers
                + 3 * d.d_model * d.d_ff * d.n_layers
                + d.d_model * d.patch) as f64;
        let attn = (4 * n * n * d.d_model * d.n_layers) as f64;
        n as f64 * per_tok + attn
    }

    fn as_native(&self) -> Option<&NativeBackend> {
        Some(self)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::model::tiny_model;

    #[test]
    fn backend_forward_and_timing() {
        let b = NativeBackend::new(tiny_model(1));
        let toks = vec![0.1f32; 8 * 4];
        let out = b.forward(&toks, 8).unwrap();
        assert_eq!(out.len(), 8 * 4);
        assert!(b.mean_secs() > 0.0);
        assert!(b.flops(8) > 0.0);
    }

    #[test]
    fn default_batch_matches_loop() {
        let b = NativeBackend::new(tiny_model(2));
        let toks: Vec<f32> = (0..2 * 8 * 4).map(|i| (i as f32 * 0.1).sin()).collect();
        let batched = b.forward_batch(&toks, 2, 8).unwrap();
        let first = b.forward(&toks[..8 * 4], 8).unwrap();
        for i in 0..8 * 4 {
            assert!((batched[i] - first[i]).abs() < 1e-5);
        }
    }

    #[test]
    fn cached_session_matches_stateless_forward() {
        let b = NativeBackend::new(tiny_model(3));
        let toks: Vec<f32> = (0..6 * 4).map(|i| (i as f32 * 0.17).sin()).collect();
        let mut sess = b.begin_cached(&toks[..3 * 4], 3).unwrap();
        let rows = sess.extend(&toks[3 * 4..], 3).unwrap();
        let full = b.forward(&toks, 6).unwrap();
        // rows = outputs at positions 2..=5.
        for i in 0..4 * 4 {
            assert!(
                (rows[i] - full[2 * 4 + i]).abs() < 1e-5,
                "cached {} vs stateless {}",
                rows[i],
                full[2 * 4 + i]
            );
        }
        let tip = sess.tip_mean().unwrap();
        for i in 0..4 {
            assert!((tip[i] - full[5 * 4 + i]).abs() < 1e-5);
        }
    }

    #[test]
    fn cached_session_eviction_matches_sliding_window() {
        // Appending past max_ctx must equal the stateless window rule:
        // forward over the last max_ctx patches.
        let b = NativeBackend::new(tiny_model(4));
        let toks: Vec<f32> = (0..12 * 4).map(|i| (i as f32 * 0.13).cos()).collect();
        let mut sess = b.begin_cached(&toks[..8 * 4], 8).unwrap();
        sess.append(&toks[8 * 4..9 * 4], 1).unwrap(); // slides to keep 7, appends 1
        assert_eq!(sess.len(), 8);
        let window = &toks[1 * 4..9 * 4];
        let full = b.forward(window, 8).unwrap();
        let tip = sess.tip_mean().unwrap();
        for i in 0..4 {
            assert!((tip[i] - full[7 * 4 + i]).abs() < 1e-5);
        }
    }
}
