//! Production backend: AOT HLO artifacts executed via PJRT.
//!
//! Shapes are compile-time fixed at `[B, n_ctx, patch]` per artifact; this
//! backend pads sequences to `n_ctx` (causality makes the padding inert for
//! positions < n) and selects the smallest batch variant that fits, padding
//! the batch with zero sequences — the same shape-specialization strategy
//! TPU serving stacks use.

use std::rc::Rc;

use anyhow::{Context, Result};

use super::Backend;
use crate::nn::ModelDims;
use crate::runtime::{Engine, Executable, Manifest};

/// PJRT-backed backend over AOT HLO artifacts with shape routing.
pub struct XlaBackend {
    name: String,
    dims: ModelDims,
    /// (batch, n_ctx, executable) shape-specialized variants.
    variants: Vec<(usize, usize, Rc<Executable>)>,
}

impl XlaBackend {
    /// Load all batch variants of `model` ("target" | "draft") with the
    /// given kernel flavor ("fused" | "pallas") from the manifest,
    /// including short-sequence variants (production shape routing).
    pub fn load(
        engine: &mut Engine,
        manifest: &Manifest,
        model: &str,
        kernel: &str,
    ) -> Result<XlaBackend> {
        Self::load_filtered(engine, manifest, model, kernel, false)
    }

    /// Like [`Self::load`] but with `full_ctx_only = true` restricted to
    /// the full-context artifacts — the paper's fixed-graph measurement
    /// protocol (one executable per model), used by the reproduction
    /// benches so cost ratios are constant across context lengths.
    pub fn load_filtered(
        engine: &mut Engine,
        manifest: &Manifest,
        model: &str,
        kernel: &str,
        full_ctx_only: bool,
    ) -> Result<XlaBackend> {
        let entry = match model {
            "target" => &manifest.target,
            "draft" => &manifest.draft,
            other => anyhow::bail!("unknown model {other}"),
        };
        let mut arts = manifest.batch_variants(model, kernel);
        if full_ctx_only {
            arts.retain(|a| a.n_ctx == manifest.n_ctx);
        }
        anyhow::ensure!(!arts.is_empty(), "no artifacts for {model}/{kernel}");
        let mut variants = Vec::new();
        for a in arts {
            let exe = engine
                .load(&a.file, (a.batch, a.n_ctx, manifest.patch))
                .with_context(|| format!("loading {}", a.file.display()))?;
            variants.push((a.batch, a.n_ctx, exe));
        }
        Ok(XlaBackend { name: format!("{}[{kernel}]", entry.name), dims: entry.dims, variants })
    }

    /// Cheapest variant fitting `b` rows of `n` patches (cost ~ b * n).
    fn variant_for(&self, b: usize, n: usize) -> Result<&(usize, usize, Rc<Executable>)> {
        self.variants
            .iter()
            .filter(|(vb, vn, _)| *vb >= b && *vn >= n)
            .min_by_key(|(vb, vn, _)| (*vb * *vn, *vn))
            .with_context(|| format!("no shape variant >= (b{b}, n{n}) for {}", self.name))
    }

    /// All (batch, n_ctx) executable shapes this backend can route to.
    pub fn available_shapes(&self) -> Vec<(usize, usize)> {
        self.variants.iter().map(|(b, n, _)| (*b, *n)).collect()
    }
}

impl Backend for XlaBackend {
    fn name(&self) -> &str {
        &self.name
    }
    fn patch(&self) -> usize {
        self.dims.patch
    }
    fn max_ctx(&self) -> usize {
        self.dims.n_ctx
    }

    fn forward(&self, tokens: &[f32], n: usize) -> Result<Vec<f32>> {
        let p = self.dims.patch;
        anyhow::ensure!(n <= self.dims.n_ctx, "n {n} > n_ctx {}", self.dims.n_ctx);
        anyhow::ensure!(tokens.len() >= n * p, "tokens too short");
        let (_, vn, exe) = self.variant_for(1, n)?;
        // Pad sequence to the variant's shape; outputs past n-1 are
        // garbage-but-unused thanks to the causal mask.
        let mut buf = vec![0.0f32; vn * p];
        buf[..n * p].copy_from_slice(&tokens[..n * p]);
        let out = exe.run(&buf)?;
        Ok(out[..n * p].to_vec())
    }

    fn forward_batch(&self, tokens: &[f32], b: usize, n: usize) -> Result<Vec<f32>> {
        let p = self.dims.patch;
        anyhow::ensure!(n <= self.dims.n_ctx);
        anyhow::ensure!(tokens.len() == b * n * p, "bad batch buffer");
        let (vb, vn, exe) = self.variant_for(b, n)?;
        let mut buf = vec![0.0f32; vb * vn * p];
        for i in 0..b {
            buf[i * vn * p..i * vn * p + n * p]
                .copy_from_slice(&tokens[i * n * p..(i + 1) * n * p]);
        }
        let out = exe.run(&buf)?;
        let mut result = Vec::with_capacity(b * n * p);
        for i in 0..b {
            result.extend_from_slice(&out[i * vn * p..i * vn * p + n * p]);
        }
        Ok(result)
    }

    fn mean_secs(&self) -> f64 {
        // Weighted mean over all variants that have run.
        let (mut t, mut n) = (0.0, 0u64);
        for (_, _, e) in &self.variants {
            if e.calls() > 0 {
                t += e.mean_secs() * e.calls() as f64;
                n += e.calls();
            }
        }
        if n == 0 {
            f64::NAN
        } else {
            t / n as f64
        }
    }

    fn flops(&self, n: usize) -> f64 {
        let d = &self.dims;
        let per_tok = 2.0
            * (d.patch * d.d_model
                + 4 * d.d_model * d.d_model * d.n_layers
                + 3 * d.d_model * d.d_ff * d.n_layers
                + d.d_model * d.patch) as f64;
        let attn = (4 * n * n * d.d_model * d.n_layers) as f64;
        n as f64 * per_tok + attn
    }
}
