//! Model backends: the abstraction the speculative-decoding engine runs
//! over. Three implementations:
//!
//! * [`XlaBackend`] — the production path: AOT HLO artifacts on PJRT.
//! * [`NativeBackend`] — pure-Rust forward (parity tests, PJRT-free benches).
//! * [`AnalyticBackend`] — closed-form AR(1) patch heads for the statistical
//!   exactness tests of the lossless variant (no NN at all).
//!
//! Decode loops do not call `forward` directly anymore: they run over
//! [`DecodeSession`]s obtained from [`begin_session`] (see the `session`
//! module and `models/README.md`). With [`CacheMode::On`] the native
//! backend serves KV-cached incremental sessions; everything else (and
//! [`CacheMode::Off`]) gets the stateless wrapper with identical
//! observable behavior.

mod analytic;
mod native;
mod session;
mod xla_backend;

pub use analytic::AnalyticBackend;
pub use native::{NativeBackend, NativeBatchSession, NativeSession};
pub use session::{
    begin_batch_session, begin_session, BatchDecodeSession, CacheMode, DecodeSession,
    StatelessBatchSession, StatelessSession,
};
pub use xla_backend::XlaBackend;

use anyhow::Result;

/// A next-patch mean predictor over patch-token sequences.
///
/// `forward` consumes a flat row-major `[n, patch]` token buffer and returns
/// flat `[n, patch]` means, where output position `i` is the predicted mean
/// of patch `i+1` given patches `0..=i` (causal). This single signature
/// serves both draft proposal steps (read the last position) and batched
/// target validation (read the last γ+1 positions) — see DESIGN.md §2.
pub trait Backend {
    /// Backend label for logs and stats.
    fn name(&self) -> &str;
    /// Values per patch token.
    fn patch(&self) -> usize;
    /// Maximum sequence length (patches) a single forward accepts.
    fn max_ctx(&self) -> usize;
    /// Single-sequence forward.
    fn forward(&self, tokens: &[f32], n: usize) -> Result<Vec<f32>>;
    /// Batched forward over `b` independent sequences of equal length
    /// (flat `[b, n, patch]`). Default: loop over `forward`.
    fn forward_batch(&self, tokens: &[f32], b: usize, n: usize) -> Result<Vec<f32>> {
        let stride = n * self.patch();
        let mut out = Vec::with_capacity(b * stride);
        for i in 0..b {
            out.extend(self.forward(&tokens[i * stride..(i + 1) * stride], n)?);
        }
        Ok(out)
    }
    /// Mean seconds per single-sequence forward, if instrumented
    /// (feeds the paper's measured cost ratio `c`).
    fn mean_secs(&self) -> f64 {
        f64::NAN
    }
    /// Dense-matmul FLOPs of one forward at length `n` (for ĉ / OpsFactor).
    fn flops(&self, n: usize) -> f64;
    /// Downcast hook for session creation: backends with a KV-cached
    /// incremental decode path return themselves here so
    /// [`begin_session`] can hand out a cached session; the default
    /// (`None`) routes to the always-correct stateless wrapper.
    fn as_native(&self) -> Option<&NativeBackend> {
        None
    }
}

/// Measured draft/target cost ratios (paper's c and ĉ).
pub fn cost_ratios(target: &dyn Backend, draft: &dyn Backend, n: usize) -> (f64, f64) {
    let c = draft.mean_secs() / target.mean_secs();
    let c_hat = draft.flops(n) / target.flops(n);
    (c, c_hat)
}
