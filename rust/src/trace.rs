//! Flight-recorder tracing: a config-gated, fixed-capacity ring buffer
//! of typed serving events with Chrome-trace export.
//!
//! The serving stack's aggregate counters ([`crate::metrics`]) answer
//! "how is the fleet doing"; they cannot answer "why was *this* request
//! slow". The flight recorder fills that gap: every request carries a
//! `request_id` from admission to reply, and every stage of its life —
//! queue wait, dispatch, each speculative round (γ, k, per-proposal α,
//! draft-vs-verify time split), the final reply — lands as one
//! fixed-size [`TraceEvent`] in a preallocated ring. Control-plane
//! transitions (controller retunes, breaker trips, replica restarts,
//! work steals, weight-swap generations) share the same ring under
//! `request_id = 0`.
//!
//! Design constraints, in priority order:
//!
//! 1. **Disabled = free.** The sink lives behind `Option<Arc<TraceSink>>`
//!    (the [`crate::faultinject::FaultPlan`] pattern): with
//!    `trace_capacity = 0` nothing is constructed and every call site is
//!    an `if let Some(..)` on a `None` — no allocation, no lock, no RNG
//!    perturbation, bit-identical serving.
//! 2. **Enabled = allocation-free after startup.** The ring is
//!    `TRACE_SHARDS` mutex-guarded slabs of `Copy` slots, preallocated
//!    in [`TraceSink::new`]. Recording is a relaxed `fetch_add` (shard
//!    pick + global order), one short mutex hold, and a slot overwrite.
//!    Variable-length payloads are clamped into fixed arrays
//!    ([`MAX_TRACE_ALPHAS`]) and interned small codes (priority, draft
//!    kind, breaker state ride as `u8`).
//! 3. **Overflow = counted drop, never a block.** The ring overwrites
//!    its oldest slot; [`TraceSink::dropped`] reports exactly how many
//!    events were overwritten (`head − capacity` per shard, summed), so
//!    a wrapped ring is visible in `/stats` rather than silently lossy.
//!
//! Export: [`TraceSink::chrome_trace_json`] renders the live ring as a
//! Chrome trace-event array (`[{name, ph, ts, dur, pid, tid, args}]`)
//! loadable in `chrome://tracing` or [Perfetto](https://ui.perfetto.dev)
//! (served at `GET /debug/trace`), and
//! [`TraceSink::request_timeline_json`] reconstructs one request's
//! timeline by id (served at `GET /debug/requests/<id>`).

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::{Duration, Instant};

use crate::util::json::Json;

/// Number of independent ring shards. Writers pick a shard from the
/// global event sequence (`seq & (TRACE_SHARDS - 1)`), so concurrent
/// replicas contend on different mutexes. Power of two.
pub const TRACE_SHARDS: usize = 8;

/// Per-round α values retained per [`EventKind::Round`] event. Rounds
/// with more proposals than this keep the first `MAX_TRACE_ALPHAS`
/// (γ is typically ≤ 8 in tuned operation; the count field is exact
/// either way).
pub const MAX_TRACE_ALPHAS: usize = 8;

/// The typed payload of one trace event. Every variant is `Copy` with a
/// fixed layout so ring slots never allocate; names/strings are interned
/// as small integer codes (`priority`: 0 low / 1 normal / 2 high;
/// `draft`: 0 model / 1 extrap / 2 adaptive; breaker `state`: 0 closed /
/// 1 open / 2 half-open).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum EventKind {
    /// A request passed admission and entered the queue.
    Admitted {
        /// Priority band code (0 low / 1 normal / 2 high).
        priority: u8,
        /// Effective deadline in ms (0 = none).
        deadline_ms: u64,
    },
    /// A replica picked the request out of the queue. Recorded as a
    /// span covering the full queue wait.
    Dispatched {
        /// Executing replica (0-based).
        replica: u32,
    },
    /// One speculative round (or AR step, with `gamma = 0`) of this
    /// request's decode. Recorded as a span covering draft + verify.
    Round {
        /// 0-based round index within the request.
        round: u32,
        /// Proposals drafted per branch this round (0 = pure AR step).
        gamma: u8,
        /// Candidate branches drafted (tree width; 1 = classic chain).
        k: u8,
        /// Draft source code (0 model / 1 extrap / 2 adaptive).
        draft: u8,
        /// Total proposals offered to the verifier (γ · k).
        proposed: u16,
        /// Proposals accepted on the committed branch.
        accepted: u16,
        /// Proposals rolled back on the committed branch (γ − accepted).
        rollback: u16,
        /// Residual (correction) draws taken after the reject.
        residual: u16,
        /// Wall time spent drafting, nanoseconds.
        draft_ns: u64,
        /// Wall time spent in target verification, nanoseconds.
        target_ns: u64,
        /// How many of `alphas` are live.
        n_alphas: u8,
        /// Per-proposal acceptance probabilities (committed branch),
        /// first `n_alphas` entries live.
        alphas: [f32; MAX_TRACE_ALPHAS],
    },
    /// The request's reply was handed back. Recorded as a span covering
    /// the full admission→reply latency, so this is the request's root
    /// span in the Chrome view.
    Replied {
        /// Whether the reply was a success (`false` = typed error).
        ok: bool,
        /// HTTP status the reply maps to.
        status: u16,
        /// Rounds (or AR steps) the decode executed; 0 for errors.
        rounds: u32,
    },
    /// The request was shed by the bounded admission queue (HTTP 429).
    Shed {
        /// Priority band code of the shed request.
        priority: u8,
    },
    /// The request's deadline expired while queued (HTTP 504).
    Expired {
        /// The deadline the request carried, ms.
        deadline_ms: u64,
        /// How long it had waited when purged, ms.
        waited_ms: u64,
    },
    /// The request was re-queued after its replica failed mid-flight.
    Requeued,
    /// The replica executing this request panicked; the supervisor
    /// answered the request with a typed `replica_failure`.
    ReplicaFailed {
        /// The replica that failed.
        replica: u32,
    },
    /// Control plane: the (γ × k) controller moved its operating point.
    Retune {
        /// New γ.
        gamma: u8,
        /// New k.
        k: u8,
    },
    /// Control plane: the speculation circuit breaker changed state.
    Breaker {
        /// New state code (0 closed / 1 open / 2 half-open).
        state: u8,
    },
    /// Control plane: a panicked replica was restarted over the shared
    /// packed weights.
    ReplicaRestart {
        /// The restarted replica.
        replica: u32,
    },
    /// Control plane: a replica stole work from another group's queue.
    Steal {
        /// The stealing replica.
        replica: u32,
    },
    /// Control plane: a live weight swap committed a new generation.
    Swap {
        /// The generation the model slot advanced to.
        generation: u64,
    },
}

impl EventKind {
    /// The Chrome trace-event `name` this kind renders under.
    pub fn name(&self) -> &'static str {
        match self {
            EventKind::Admitted { .. } => "admitted",
            EventKind::Dispatched { .. } => "queue_wait",
            EventKind::Round { .. } => "round",
            EventKind::Replied { .. } => "request",
            EventKind::Shed { .. } => "shed",
            EventKind::Expired { .. } => "deadline_expired",
            EventKind::Requeued => "requeued",
            EventKind::ReplicaFailed { .. } => "replica_failed",
            EventKind::Retune { .. } => "retune",
            EventKind::Breaker { .. } => "breaker",
            EventKind::ReplicaRestart { .. } => "replica_restart",
            EventKind::Steal { .. } => "steal",
            EventKind::Swap { .. } => "swap",
        }
    }
}

/// One recorded event: a fixed-size `Copy` ring slot.
#[derive(Clone, Copy, Debug)]
pub struct TraceEvent {
    /// Global record order (total order across shards).
    pub seq: u64,
    /// Event start, microseconds since the sink's epoch.
    pub ts_us: u64,
    /// Event duration in microseconds (0 for instants).
    pub dur_us: u64,
    /// Owning request (0 = control plane).
    pub request_id: u64,
    /// Typed payload.
    pub kind: EventKind,
}

struct Shard {
    slots: Vec<TraceEvent>,
    /// Monotone write count for this shard. The live window is the last
    /// `min(head, slots.len())` writes; everything older was overwritten
    /// (= dropped).
    head: u64,
}

/// The flight recorder: `TRACE_SHARDS` preallocated rings behind short
/// mutexes, plus a global sequence counter. Construct once at engine
/// start (when `trace_capacity > 0`) and share via `Arc`.
pub struct TraceSink {
    epoch: Instant,
    shards: Vec<Mutex<Shard>>,
    shard_cap: usize,
    seq: AtomicU64,
}

impl TraceSink {
    /// Preallocate a recorder holding at least `capacity` events
    /// (rounded up to a multiple of [`TRACE_SHARDS`]). All ring memory
    /// is allocated here; recording never allocates.
    pub fn new(capacity: usize) -> TraceSink {
        let shard_cap = capacity.div_ceil(TRACE_SHARDS).max(1);
        let zero = TraceEvent {
            seq: 0,
            ts_us: 0,
            dur_us: 0,
            request_id: 0,
            kind: EventKind::Requeued,
        };
        let shards = (0..TRACE_SHARDS)
            .map(|_| Mutex::new(Shard { slots: vec![zero; shard_cap], head: 0 }))
            .collect();
        TraceSink { epoch: Instant::now(), shards, shard_cap, seq: AtomicU64::new(0) }
    }

    /// Total ring capacity in events (shards × per-shard slots).
    pub fn capacity(&self) -> usize {
        self.shard_cap * TRACE_SHARDS
    }

    /// Total events ever recorded (including overwritten ones).
    pub fn recorded(&self) -> u64 {
        self.shards.iter().map(|s| s.lock().unwrap().head).sum()
    }

    /// Exact count of events lost to ring wraparound: per shard,
    /// `head − shard_cap` once the shard has wrapped.
    pub fn dropped(&self) -> u64 {
        self.shards
            .iter()
            .map(|s| s.lock().unwrap().head.saturating_sub(self.shard_cap as u64))
            .sum()
    }

    fn now_us(&self) -> u64 {
        self.epoch.elapsed().as_micros() as u64
    }

    /// Record an instant event stamped "now".
    pub fn record(&self, request_id: u64, kind: EventKind) {
        let ts = self.now_us();
        self.record_at(request_id, ts, 0, kind);
    }

    /// Record a span that ends now and lasted `dur` (the common shape:
    /// the caller measures a stage, then records it on completion; the
    /// start timestamp is back-computed).
    pub fn record_span_ending_now(&self, request_id: u64, dur: Duration, kind: EventKind) {
        let dur_us = dur.as_micros() as u64;
        let ts = self.now_us().saturating_sub(dur_us);
        self.record_at(request_id, ts, dur_us, kind);
    }

    fn record_at(&self, request_id: u64, ts_us: u64, dur_us: u64, kind: EventKind) {
        let seq = self.seq.fetch_add(1, Ordering::Relaxed);
        let shard = &self.shards[(seq as usize) & (TRACE_SHARDS - 1)];
        let mut s = shard.lock().unwrap();
        let idx = (s.head % self.shard_cap as u64) as usize;
        s.slots[idx] = TraceEvent { seq, ts_us, dur_us, request_id, kind };
        s.head += 1;
    }

    /// Copy out every live (not-yet-overwritten) event, globally ordered
    /// by record sequence. Allocates (debug path, not the hot path).
    pub fn snapshot(&self) -> Vec<TraceEvent> {
        let mut out = Vec::with_capacity(self.capacity());
        for shard in &self.shards {
            let s = shard.lock().unwrap();
            let live = (s.head).min(self.shard_cap as u64) as usize;
            let start = (s.head - live as u64) as u64;
            for i in 0..live {
                let idx = ((start + i as u64) % self.shard_cap as u64) as usize;
                out.push(s.slots[idx]);
            }
        }
        out.sort_by_key(|e| e.seq);
        out
    }

    /// Render the live ring as a Chrome trace-event array: every event a
    /// complete (`"ph": "X"`) event with `ts`/`dur` in microseconds,
    /// `pid` 1, and `tid` the low 32 bits of the owning request id
    /// (0 = control plane). The full request id rides in `args.rid`.
    /// Load the output in `chrome://tracing` or Perfetto as-is.
    pub fn chrome_trace_json(&self) -> Json {
        Json::Arr(self.snapshot().iter().map(chrome_event).collect())
    }

    /// Reconstruct one request's timeline: every live event stamped with
    /// `request_id`, in record order, as
    /// `{"request_id": "<16-hex>", "found": n, "events": [...]}`.
    pub fn request_timeline_json(&self, request_id: u64) -> Json {
        let events: Vec<Json> = self
            .snapshot()
            .iter()
            .filter(|e| e.request_id == request_id)
            .map(timeline_event)
            .collect();
        Json::obj(vec![
            ("request_id", Json::from(format_request_id(request_id))),
            ("found", Json::from(events.len())),
            ("events", Json::Arr(events)),
        ])
    }

    /// The `"trace"` block served in `/stats`:
    /// `{"enabled": true, "capacity": n, "recorded": n, "dropped": n}`.
    pub fn stats_json(&self) -> Json {
        Json::obj(vec![
            ("enabled", Json::from(true)),
            ("capacity", Json::from(self.capacity())),
            ("recorded", Json::from(self.recorded() as usize)),
            ("dropped", Json::from(self.dropped() as usize)),
        ])
    }
}

/// Canonical wire spelling of a request id: 16 lowercase hex digits
/// (echoed in responses, `X-Request-Id`, and trace output).
pub fn format_request_id(rid: u64) -> String {
    format!("{rid:016x}")
}

/// Parse a client-supplied request id: 1–16 hex digits (the canonical
/// form), with an optional `0x` prefix. Returns `None` for anything
/// else. Id 0 is reserved for the control plane and rejected.
pub fn parse_request_id(s: &str) -> Option<u64> {
    let hex = s.strip_prefix("0x").unwrap_or(s);
    if hex.is_empty() || hex.len() > 16 {
        return None;
    }
    // from_str_radix alone would also take a leading '+'; ids are bare
    // hex digits only.
    if !hex.bytes().all(|b| b.is_ascii_hexdigit()) {
        return None;
    }
    match u64::from_str_radix(hex, 16) {
        Ok(0) => None,
        Ok(v) => Some(v),
        Err(_) => None,
    }
}

fn prio_name(p: u8) -> &'static str {
    match p {
        0 => "low",
        1 => "normal",
        2 => "high",
        _ => "unknown",
    }
}

fn draft_name(d: u8) -> &'static str {
    match d {
        0 => "model",
        1 => "extrap",
        2 => "adaptive",
        _ => "unknown",
    }
}

fn breaker_name(b: u8) -> &'static str {
    match b {
        0 => "closed",
        1 => "open",
        2 => "half_open",
        _ => "unknown",
    }
}

fn args_json(e: &TraceEvent) -> Json {
    let mut fields: Vec<(&str, Json)> =
        vec![("rid", Json::from(format_request_id(e.request_id)))];
    match e.kind {
        EventKind::Admitted { priority, deadline_ms } => {
            fields.push(("priority", Json::from(prio_name(priority))));
            fields.push(("deadline_ms", Json::from(deadline_ms as usize)));
        }
        EventKind::Dispatched { replica } => {
            fields.push(("replica", Json::from(replica as usize)));
        }
        EventKind::Round {
            round,
            gamma,
            k,
            draft,
            proposed,
            accepted,
            rollback,
            residual,
            draft_ns,
            target_ns,
            n_alphas,
            alphas,
        } => {
            fields.push(("round", Json::from(round as usize)));
            fields.push(("gamma", Json::from(gamma as usize)));
            fields.push(("k", Json::from(k as usize)));
            fields.push(("draft", Json::from(draft_name(draft))));
            fields.push(("proposed", Json::from(proposed as usize)));
            fields.push(("accepted", Json::from(accepted as usize)));
            fields.push(("rollback", Json::from(rollback as usize)));
            fields.push(("residual", Json::from(residual as usize)));
            fields.push(("draft_ns", Json::from(draft_ns as usize)));
            fields.push(("target_ns", Json::from(target_ns as usize)));
            let live: Vec<f64> =
                alphas[..(n_alphas as usize).min(MAX_TRACE_ALPHAS)].iter().map(|&a| a as f64).collect();
            fields.push(("alphas", Json::arr_f64(&live)));
        }
        EventKind::Replied { ok, status, rounds } => {
            fields.push(("ok", Json::from(ok)));
            fields.push(("status", Json::from(status as usize)));
            fields.push(("rounds", Json::from(rounds as usize)));
        }
        EventKind::Shed { priority } => {
            fields.push(("priority", Json::from(prio_name(priority))));
        }
        EventKind::Expired { deadline_ms, waited_ms } => {
            fields.push(("deadline_ms", Json::from(deadline_ms as usize)));
            fields.push(("waited_ms", Json::from(waited_ms as usize)));
        }
        EventKind::Requeued => {}
        EventKind::ReplicaFailed { replica } => {
            fields.push(("replica", Json::from(replica as usize)));
        }
        EventKind::Retune { gamma, k } => {
            fields.push(("gamma", Json::from(gamma as usize)));
            fields.push(("k", Json::from(k as usize)));
        }
        EventKind::Breaker { state } => {
            fields.push(("state", Json::from(breaker_name(state))));
        }
        EventKind::ReplicaRestart { replica } => {
            fields.push(("replica", Json::from(replica as usize)));
        }
        EventKind::Steal { replica } => {
            fields.push(("replica", Json::from(replica as usize)));
        }
        EventKind::Swap { generation } => {
            fields.push(("generation", Json::from(generation as usize)));
        }
    }
    Json::obj(fields)
}

fn chrome_event(e: &TraceEvent) -> Json {
    Json::obj(vec![
        ("name", Json::from(e.kind.name())),
        ("ph", Json::from("X")),
        ("ts", Json::from(e.ts_us as usize)),
        ("dur", Json::from(e.dur_us as usize)),
        ("pid", Json::from(1usize)),
        ("tid", Json::from((e.request_id & 0xFFFF_FFFF) as usize)),
        ("args", args_json(e)),
    ])
}

fn timeline_event(e: &TraceEvent) -> Json {
    Json::obj(vec![
        ("name", Json::from(e.kind.name())),
        ("seq", Json::from(e.seq as usize)),
        ("ts_us", Json::from(e.ts_us as usize)),
        ("dur_us", Json::from(e.dur_us as usize)),
        ("args", args_json(e)),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_and_counts_without_wrap() {
        let sink = TraceSink::new(64);
        assert_eq!(sink.capacity(), 64);
        for i in 0..10u64 {
            sink.record(i + 1, EventKind::Requeued);
        }
        assert_eq!(sink.recorded(), 10);
        assert_eq!(sink.dropped(), 0);
        let snap = sink.snapshot();
        assert_eq!(snap.len(), 10);
        // Global sequence order is preserved across shards.
        for (i, e) in snap.iter().enumerate() {
            assert_eq!(e.seq, i as u64);
            assert_eq!(e.request_id, i as u64 + 1);
        }
    }

    #[test]
    fn wraparound_drop_accounting_is_exact() {
        let sink = TraceSink::new(16); // 2 slots per shard
        let total = 100u64;
        for i in 0..total {
            sink.record(i, EventKind::Requeued);
        }
        assert_eq!(sink.recorded(), total);
        // Uniform round-robin: every shard wrapped identically.
        assert_eq!(sink.dropped(), total - sink.capacity() as u64);
        let snap = sink.snapshot();
        assert_eq!(snap.len(), sink.capacity());
        // The survivors are exactly the newest `capacity` events.
        assert!(snap.iter().all(|e| e.seq >= total - sink.capacity() as u64));
    }

    #[test]
    fn capacity_rounds_up_to_shards() {
        let sink = TraceSink::new(1);
        assert_eq!(sink.capacity(), TRACE_SHARDS);
        let sink = TraceSink::new(0);
        assert_eq!(sink.capacity(), TRACE_SHARDS);
        let sink = TraceSink::new(17);
        assert_eq!(sink.capacity() % TRACE_SHARDS, 0);
        assert!(sink.capacity() >= 17);
    }

    #[test]
    fn chrome_export_parses_and_has_required_keys() {
        let sink = TraceSink::new(32);
        sink.record(7, EventKind::Admitted { priority: 1, deadline_ms: 250 });
        sink.record_span_ending_now(
            7,
            Duration::from_micros(1500),
            EventKind::Replied { ok: true, status: 200, rounds: 3 },
        );
        sink.record(0, EventKind::Breaker { state: 1 });
        let text = sink.chrome_trace_json().to_string();
        let parsed = Json::parse(&text).unwrap();
        let arr = parsed.as_arr().unwrap();
        assert_eq!(arr.len(), 3);
        for e in arr {
            assert_eq!(e.get("ph").unwrap().as_str(), Some("X"));
            assert!(e.get("name").unwrap().as_str().is_some());
            assert!(e.get("ts").unwrap().as_usize().is_some());
            assert!(e.get("dur").unwrap().as_usize().is_some());
            assert_eq!(e.get("pid").unwrap().as_usize(), Some(1));
            assert!(e.get("tid").unwrap().as_usize().is_some());
            assert!(e.get("args").unwrap().as_obj().is_some());
        }
        // The reply span back-computes its start and keeps its duration.
        let reply = arr.iter().find(|e| e.get("name").unwrap().as_str() == Some("request")).unwrap();
        assert_eq!(reply.get("dur").unwrap().as_usize(), Some(1500));
        assert_eq!(reply.get("args").unwrap().get("rounds").unwrap().as_usize(), Some(3));
    }

    #[test]
    fn timeline_filters_by_request() {
        let sink = TraceSink::new(32);
        sink.record(5, EventKind::Admitted { priority: 2, deadline_ms: 0 });
        sink.record(6, EventKind::Admitted { priority: 0, deadline_ms: 0 });
        sink.record(5, EventKind::Replied { ok: true, status: 200, rounds: 1 });
        let j = sink.request_timeline_json(5);
        assert_eq!(j.get("request_id").unwrap().as_str(), Some("0000000000000005"));
        assert_eq!(j.get("found").unwrap().as_usize(), Some(2));
        let names: Vec<&str> = j
            .get("events")
            .unwrap()
            .as_arr()
            .unwrap()
            .iter()
            .map(|e| e.get("name").unwrap().as_str().unwrap())
            .collect();
        assert_eq!(names, vec!["admitted", "request"]);
    }

    #[test]
    fn round_event_clamps_alphas() {
        let sink = TraceSink::new(32);
        let mut alphas = [0f32; MAX_TRACE_ALPHAS];
        alphas[0] = 0.75;
        alphas[1] = 0.5;
        sink.record(9, EventKind::Round {
            round: 0,
            gamma: 2,
            k: 1,
            draft: 0,
            proposed: 2,
            accepted: 1,
            rollback: 1,
            residual: 1,
            draft_ns: 100,
            target_ns: 400,
            n_alphas: 2,
            alphas,
        });
        let j = sink.request_timeline_json(9);
        let e = j.get("events").unwrap().at(0).unwrap();
        let a = e.get("args").unwrap().get("alphas").unwrap();
        assert_eq!(a.as_arr().unwrap().len(), 2);
        assert!((a.at(0).unwrap().as_f64().unwrap() - 0.75).abs() < 1e-6);
        assert_eq!(e.get("args").unwrap().get("draft").unwrap().as_str(), Some("model"));
    }

    #[test]
    fn request_id_wire_format_roundtrips() {
        assert_eq!(format_request_id(0x1a2b), "0000000000001a2b");
        assert_eq!(parse_request_id("0000000000001a2b"), Some(0x1a2b));
        assert_eq!(parse_request_id("0x1a2b"), Some(0x1a2b));
        assert_eq!(parse_request_id("ff"), Some(255));
        assert_eq!(parse_request_id(""), None);
        assert_eq!(parse_request_id("0"), None, "id 0 is the control plane");
        assert_eq!(parse_request_id("zz"), None);
        assert_eq!(parse_request_id("11112222333344445"), None, "too long");
    }

    #[test]
    fn stats_block_shape() {
        let sink = TraceSink::new(16);
        sink.record(1, EventKind::Requeued);
        let j = sink.stats_json();
        assert_eq!(j.get("enabled").unwrap().as_bool(), Some(true));
        assert_eq!(j.get("capacity").unwrap().as_usize(), Some(16));
        assert_eq!(j.get("recorded").unwrap().as_usize(), Some(1));
        assert_eq!(j.get("dropped").unwrap().as_usize(), Some(0));
    }

    #[test]
    fn concurrent_recording_keeps_exact_accounting() {
        use std::sync::Arc;
        let sink = Arc::new(TraceSink::new(64));
        let threads: Vec<_> = (0..4)
            .map(|t| {
                let s = Arc::clone(&sink);
                std::thread::spawn(move || {
                    for i in 0..500u64 {
                        s.record(t * 1000 + i, EventKind::Requeued);
                    }
                })
            })
            .collect();
        for t in threads {
            t.join().unwrap();
        }
        assert_eq!(sink.recorded(), 2000);
        assert_eq!(sink.recorded() - sink.dropped(), sink.capacity() as u64);
        assert_eq!(sink.snapshot().len(), sink.capacity());
    }
}
