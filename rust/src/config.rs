//! Configuration system: typed config structs, JSON config files, and a
//! small CLI argument parser (clap is unavailable offline).
//!
//! Precedence: defaults < config file (--config path.json) < CLI flags.

use std::collections::BTreeMap;
use std::path::PathBuf;

use anyhow::{bail, Context, Result};

use crate::accept::AcceptancePolicy;
use crate::models::CacheMode;
use crate::specdec::{Emission, SpecConfig, Variant};
use crate::util::json::Json;

/// Parsed command line: positional args + `--key value` / `--flag` options.
#[derive(Clone, Debug, Default)]
pub struct Cli {
    pub positional: Vec<String>,
    pub options: BTreeMap<String, String>,
}

impl Cli {
    pub fn parse(args: impl IntoIterator<Item = String>) -> Result<Cli> {
        let mut cli = Cli::default();
        let mut it = args.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(key) = a.strip_prefix("--") {
                if key.is_empty() {
                    bail!("bare '--' not supported");
                }
                if let Some((k, v)) = key.split_once('=') {
                    cli.options.insert(k.to_string(), v.to_string());
                } else if it.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                    cli.options.insert(key.to_string(), it.next().unwrap());
                } else {
                    cli.options.insert(key.to_string(), "true".to_string());
                }
            } else {
                cli.positional.push(a);
            }
        }
        Ok(cli)
    }

    pub fn from_env() -> Result<Cli> {
        Cli::parse(std::env::args().skip(1))
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(String::as_str)
    }

    pub fn get_f64(&self, key: &str) -> Result<Option<f64>> {
        self.get(key)
            .map(|v| v.parse::<f64>().with_context(|| format!("--{key} must be a number")))
            .transpose()
    }

    pub fn get_usize(&self, key: &str) -> Result<Option<usize>> {
        self.get(key)
            .map(|v| v.parse::<usize>().with_context(|| format!("--{key} must be an integer")))
            .transpose()
    }

    pub fn flag(&self, key: &str) -> bool {
        matches!(self.get(key), Some("true") | Some("1"))
    }
}

/// Server/engine configuration.
#[derive(Clone, Debug)]
pub struct ServeConfig {
    pub bind: String,
    pub http_workers: usize,
    /// Dynamic batcher: flush when this many requests are queued...
    pub max_batch: usize,
    /// ...or when the oldest request has waited this long.
    pub max_wait_ms: u64,
    /// "xla" | "native"; kernel flavor for xla: "fused" | "pallas".
    pub backend: String,
    pub kernel: String,
    pub gamma: usize,
    pub sigma: f64,
    pub bias: f64,
    pub lossless: bool,
    /// Generative (sampled) emission instead of production mean emission.
    pub sampled: bool,
    /// Adaptive γ from the acceptance monitor (Prop. 3 online).
    pub adaptive_gamma: bool,
    /// Disable speculative decoding entirely (target-only AR) — the
    /// baseline mode for A/B latency comparisons.
    pub baseline: bool,
    /// KV-cached decode sessions (default on). `false` forces the
    /// stateless re-forward cost model — outputs identical, wall-clock
    /// isn't; the A/B switch behind the cached-vs-uncached bench columns.
    pub cache: bool,
    /// Worker threads for the native kernel layer's shared compute pool
    /// (row-parallel prefill matmuls + the batched-verify fan-out).
    /// 0 = auto (`STRIDE_THREADS` env, else available parallelism capped
    /// at 8). Results are bitwise identical for any value.
    pub threads: usize,
    pub artifacts: PathBuf,
    pub seed: u64,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            bind: "127.0.0.1:8080".into(),
            http_workers: 8,
            max_batch: 8,
            max_wait_ms: 2,
            backend: "xla".into(),
            kernel: "fused".into(),
            gamma: 3,
            sigma: 0.5,
            bias: 1.0,
            lossless: false,
            sampled: false,
            adaptive_gamma: false,
            baseline: false,
            cache: true,
            threads: 0,
            artifacts: crate::artifacts_dir(),
            seed: 0xC0FFEE,
        }
    }
}

impl ServeConfig {
    /// Apply a JSON config object (subset of fields).
    pub fn apply_json(&mut self, j: &Json) -> Result<()> {
        let obj = j.as_obj().context("config must be a JSON object")?;
        for (k, v) in obj {
            match k.as_str() {
                "bind" => self.bind = v.as_str().context("bind")?.to_string(),
                "http_workers" => self.http_workers = v.as_usize().context("http_workers")?,
                "max_batch" => self.max_batch = v.as_usize().context("max_batch")?,
                "max_wait_ms" => self.max_wait_ms = v.as_usize().context("max_wait_ms")? as u64,
                "backend" => self.backend = v.as_str().context("backend")?.to_string(),
                "kernel" => self.kernel = v.as_str().context("kernel")?.to_string(),
                "gamma" => self.gamma = v.as_usize().context("gamma")?,
                "sigma" => self.sigma = v.as_f64().context("sigma")?,
                "bias" => self.bias = v.as_f64().context("bias")?,
                "lossless" => self.lossless = v.as_bool().context("lossless")?,
                "sampled" => self.sampled = v.as_bool().context("sampled")?,
                "adaptive_gamma" => self.adaptive_gamma = v.as_bool().context("adaptive_gamma")?,
                "baseline" => self.baseline = v.as_bool().context("baseline")?,
                "cache" => self.cache = v.as_bool().context("cache")?,
                "threads" => self.threads = v.as_usize().context("threads")?,
                "artifacts" => self.artifacts = PathBuf::from(v.as_str().context("artifacts")?),
                "seed" => self.seed = v.as_usize().context("seed")? as u64,
                other => bail!("unknown config key: {other}"),
            }
        }
        Ok(())
    }

    /// Apply CLI overrides.
    pub fn apply_cli(&mut self, cli: &Cli) -> Result<()> {
        if let Some(path) = cli.get("config") {
            let text = std::fs::read_to_string(path)
                .with_context(|| format!("reading config {path}"))?;
            self.apply_json(&Json::parse(&text)?)?;
        }
        if let Some(v) = cli.get("bind") {
            self.bind = v.to_string();
        }
        if let Some(v) = cli.get_usize("http-workers")? {
            self.http_workers = v;
        }
        if let Some(v) = cli.get_usize("max-batch")? {
            self.max_batch = v;
        }
        if let Some(v) = cli.get_usize("max-wait-ms")? {
            self.max_wait_ms = v as u64;
        }
        if let Some(v) = cli.get("backend") {
            self.backend = v.to_string();
        }
        if let Some(v) = cli.get("kernel") {
            self.kernel = v.to_string();
        }
        if let Some(v) = cli.get_usize("gamma")? {
            self.gamma = v;
        }
        if let Some(v) = cli.get_f64("sigma")? {
            self.sigma = v;
        }
        if let Some(v) = cli.get_f64("bias")? {
            self.bias = v;
        }
        if cli.flag("lossless") {
            self.lossless = true;
        }
        if cli.flag("sampled") {
            self.sampled = true;
        }
        if cli.flag("adaptive-gamma") {
            self.adaptive_gamma = true;
        }
        if cli.flag("baseline") {
            self.baseline = true;
        }
        // `--no-cache` switches to the stateless cost model; `--cache`
        // re-enables it (later flag wins when both are given via file+CLI).
        if cli.flag("no-cache") {
            self.cache = false;
        } else if cli.flag("cache") {
            self.cache = true;
        }
        if let Some(v) = cli.get_usize("threads")? {
            self.threads = v;
        }
        if let Some(v) = cli.get("artifacts") {
            self.artifacts = PathBuf::from(v);
        }
        if let Some(v) = cli.get_usize("seed")? {
            self.seed = v as u64;
        }
        self.validate()
    }

    pub fn validate(&self) -> Result<()> {
        if self.gamma == 0 || self.gamma > 64 {
            bail!("gamma must be in [1, 64], got {}", self.gamma);
        }
        if !(self.sigma > 0.0) {
            bail!("sigma must be positive");
        }
        if !(self.bias > 0.0) {
            bail!("bias must be positive");
        }
        if self.lossless && (self.bias - 1.0).abs() > 1e-12 {
            bail!("lossless requires bias = 1 (canonical acceptance)");
        }
        if self.lossless && !self.sampled {
            bail!("lossless requires --sampled emission (Theorems 1-2 are about the sampled chain)");
        }
        if !matches!(self.backend.as_str(), "xla" | "native") {
            bail!("backend must be 'xla' or 'native'");
        }
        if !matches!(self.kernel.as_str(), "fused" | "pallas") {
            bail!("kernel must be 'fused' or 'pallas'");
        }
        Ok(())
    }

    pub fn spec_config(&self) -> SpecConfig {
        SpecConfig {
            gamma: self.gamma,
            policy: AcceptancePolicy::new(self.sigma, self.bias),
            variant: if self.lossless { Variant::Lossless } else { Variant::Practical },
            seed: self.seed,
            max_residual_draws: 10_000,
            emission: if self.sampled { Emission::Sampled } else { Emission::Mean },
            cache: if self.cache { CacheMode::On } else { CacheMode::Off },
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args(s: &str) -> Vec<String> {
        s.split_whitespace().map(String::from).collect()
    }

    #[test]
    fn cli_parsing() {
        let c = Cli::parse(args("serve --gamma 5 --sigma=0.7 --lossless --bind 0.0.0.0:9")).unwrap();
        assert_eq!(c.positional, vec!["serve"]);
        assert_eq!(c.get("gamma"), Some("5"));
        assert_eq!(c.get("sigma"), Some("0.7"));
        assert!(c.flag("lossless"));
        assert_eq!(c.get("bind"), Some("0.0.0.0:9"));
    }

    #[test]
    fn config_precedence_and_validation() {
        let mut cfg = ServeConfig::default();
        cfg.apply_json(&Json::parse(r#"{"gamma": 7, "sigma": 0.35}"#).unwrap()).unwrap();
        assert_eq!(cfg.gamma, 7);
        let cli = Cli::parse(args("--gamma 2")).unwrap();
        cfg.apply_cli(&cli).unwrap();
        assert_eq!(cfg.gamma, 2);
        assert!((cfg.sigma - 0.35).abs() < 1e-12);
    }

    #[test]
    fn rejects_bad_values() {
        let mut cfg = ServeConfig::default();
        assert!(cfg.apply_json(&Json::parse(r#"{"gamma": 0}"#).unwrap()).is_ok());
        assert!(cfg.validate().is_err()); // gamma 0 invalid
        let mut cfg = ServeConfig::default();
        assert!(cfg.apply_json(&Json::parse(r#"{"nope": 1}"#).unwrap()).is_err());
        let mut cfg = ServeConfig::default();
        cfg.lossless = true;
        cfg.bias = 1.5;
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn spec_config_mapping() {
        let mut cfg = ServeConfig::default();
        cfg.gamma = 4;
        cfg.sigma = 0.6;
        let sc = cfg.spec_config();
        assert_eq!(sc.gamma, 4);
        assert_eq!(sc.emission, Emission::Mean);
        assert!((sc.policy.sigma - 0.6).abs() < 1e-12);
        assert_eq!(sc.cache, CacheMode::On);
    }

    #[test]
    fn threads_plumbing() {
        let mut cfg = ServeConfig::default();
        assert_eq!(cfg.threads, 0, "default must be auto");
        cfg.apply_json(&Json::parse(r#"{"threads": 4}"#).unwrap()).unwrap();
        assert_eq!(cfg.threads, 4);
        let cli = Cli::parse(args("--threads 2")).unwrap();
        cfg.apply_cli(&cli).unwrap();
        assert_eq!(cfg.threads, 2);
    }

    #[test]
    fn cache_toggle_plumbing() {
        let mut cfg = ServeConfig::default();
        assert!(cfg.cache);
        cfg.apply_json(&Json::parse(r#"{"cache": false}"#).unwrap()).unwrap();
        assert!(!cfg.cache);
        assert_eq!(cfg.spec_config().cache, CacheMode::Off);
        let cli = Cli::parse(args("--cache")).unwrap();
        cfg.apply_cli(&cli).unwrap();
        assert!(cfg.cache);
        let cli = Cli::parse(args("--no-cache")).unwrap();
        cfg.apply_cli(&cli).unwrap();
        assert!(!cfg.cache);
    }
}
