//! Configuration system: typed config structs, JSON config files, and a
//! small CLI argument parser (clap is unavailable offline).
//!
//! Precedence: defaults < config file (--config path.json) < CLI flags.

use std::collections::BTreeMap;
use std::path::PathBuf;

use anyhow::{bail, Context, Result};

use crate::accept::AcceptancePolicy;
use crate::faultinject::FaultConfig;
use crate::models::CacheMode;
use crate::specdec::{AdaptiveConfig, DraftConfig, DraftKind, Emission, SpecConfig, Variant};
use crate::util::json::Json;

/// Parsed command line: positional args + `--key value` / `--flag` options.
#[derive(Clone, Debug, Default)]
pub struct Cli {
    /// Positional arguments in order (e.g. the subcommand).
    pub positional: Vec<String>,
    /// `--key value` / `--key=value` / bare `--flag` options (flags store
    /// the string `"true"`).
    pub options: BTreeMap<String, String>,
}

impl Cli {
    /// Parse an argument iterator (without the program name).
    pub fn parse(args: impl IntoIterator<Item = String>) -> Result<Cli> {
        let mut cli = Cli::default();
        let mut it = args.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(key) = a.strip_prefix("--") {
                if key.is_empty() {
                    bail!("bare '--' not supported");
                }
                if let Some((k, v)) = key.split_once('=') {
                    cli.options.insert(k.to_string(), v.to_string());
                } else if it.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                    cli.options.insert(key.to_string(), it.next().unwrap());
                } else {
                    cli.options.insert(key.to_string(), "true".to_string());
                }
            } else {
                cli.positional.push(a);
            }
        }
        Ok(cli)
    }

    /// Parse the process arguments.
    pub fn from_env() -> Result<Cli> {
        Cli::parse(std::env::args().skip(1))
    }

    /// Raw string value of `--key`, if present.
    pub fn get(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(String::as_str)
    }

    /// `--key` parsed as a float (error when present but malformed).
    pub fn get_f64(&self, key: &str) -> Result<Option<f64>> {
        self.get(key)
            .map(|v| v.parse::<f64>().with_context(|| format!("--{key} must be a number")))
            .transpose()
    }

    /// `--key` parsed as an unsigned integer (error when malformed).
    pub fn get_usize(&self, key: &str) -> Result<Option<usize>> {
        self.get(key)
            .map(|v| v.parse::<usize>().with_context(|| format!("--{key} must be an integer")))
            .transpose()
    }

    /// Whether boolean `--key` was given (accepts `true`/`1`).
    pub fn flag(&self, key: &str) -> bool {
        matches!(self.get(key), Some("true") | Some("1"))
    }
}

/// Dispatch-ordering policy of the serving scheduler.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum SchedPolicy {
    /// Priority bands first, earliest-deadline-first within a band,
    /// arrival order as the tiebreak; saturated admission evicts the
    /// worst queued job for a higher-priority arrival. The default.
    #[default]
    Edf,
    /// Pure arrival order, tail-drop admission — the pre-scheduler
    /// batcher's behavior, kept as the A/B baseline for
    /// `benches/serving_load.rs`.
    Fifo,
}

impl SchedPolicy {
    /// Config/CLI name (`"edf"` / `"fifo"`).
    pub fn as_str(&self) -> &'static str {
        match self {
            SchedPolicy::Edf => "edf",
            SchedPolicy::Fifo => "fifo",
        }
    }

    /// Parse a config/CLI name; `None` for unknown spellings.
    pub fn parse(s: &str) -> Option<SchedPolicy> {
        match s {
            "edf" => Some(SchedPolicy::Edf),
            "fifo" => Some(SchedPolicy::Fifo),
            _ => None,
        }
    }
}

/// What happens to learned per-model state — adaptive draft heads and
/// the γ/k controller — when the replica pool live-swaps to a new model.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum SwapHeads {
    /// Discard heads and reset the controller to its warm-up state: the
    /// new weights get a clean slate. The default — learned residuals
    /// against the old target are noise against the new one.
    #[default]
    Reset,
    /// Carry heads and controller state across the swap: right when the
    /// new model is a small delta of the old (a fine-tune step) and
    /// re-warming costs more than the stale-state bias.
    Carry,
}

impl SwapHeads {
    /// Config/CLI name (`"reset"` / `"carry"`).
    pub fn as_str(&self) -> &'static str {
        match self {
            SwapHeads::Reset => "reset",
            SwapHeads::Carry => "carry",
        }
    }

    /// Parse a config/CLI name; `None` for unknown spellings.
    pub fn parse(s: &str) -> Option<SwapHeads> {
        match s {
            "reset" => Some(SwapHeads::Reset),
            "carry" => Some(SwapHeads::Carry),
            _ => None,
        }
    }
}

/// Server/engine configuration.
#[derive(Clone, Debug)]
pub struct ServeConfig {
    /// Listen address, `host:port` (port 0 picks an ephemeral port).
    pub bind: String,
    /// HTTP worker threads (connection handling only; model work runs on
    /// the engine replica threads).
    pub http_workers: usize,
    /// Dynamic batcher: flush when this many requests are queued...
    pub max_batch: usize,
    /// ...or when the oldest request has waited this long.
    pub max_wait_ms: u64,
    /// Engine replicas: independent model/session stacks (sharing one
    /// `Arc`-packed weight storage on the native backend), each draining
    /// the admission queue with group affinity + idle stealing. The
    /// PJRT-backed `xla` backend is not shareable across threads, so it
    /// requires `replicas = 1`.
    pub replicas: usize,
    /// Hard cap on queued (admitted, not yet dispatched) requests. At
    /// the cap, arrivals are shed with HTTP 429 (`Retry-After`) — under
    /// [`SchedPolicy::Edf`], a higher-priority arrival instead evicts
    /// the worst queued job.
    pub queue_cap: usize,
    /// Dispatch ordering: `edf` (priority + earliest-deadline-first) or
    /// `fifo` (arrival order; the A/B baseline).
    pub sched: SchedPolicy,
    /// Deadline applied to requests that carry none, in milliseconds
    /// from admission (0 = no default deadline). Expired jobs are failed
    /// fast with HTTP 504 and never decoded.
    pub default_deadline_ms: u64,
    /// `Retry-After` hint attached to shed responses, in milliseconds.
    pub retry_after_ms: u64,
    /// "xla" | "native"; kernel flavor for xla: "fused" | "pallas".
    pub backend: String,
    /// XLA kernel flavor ("fused" | "pallas"); ignored by `native`.
    pub kernel: String,
    /// Default draft block length γ (per-request `gamma` overrides; the
    /// adaptive controller's opening value).
    pub gamma: usize,
    /// Default tree branch count k (per-request `k` overrides). `1` is
    /// the classic single-trajectory engine; `k > 1` drafts k candidate
    /// continuations per round and commits the longest accepted branch
    /// (`specdec::sd_generate_tree`). Requires the practical variant.
    pub k: usize,
    /// Default acceptance width σ (per-request `sigma` overrides).
    pub sigma: f64,
    /// Acceptance bias λ (1.0 = canonical rule).
    pub bias: f64,
    /// Run the lossless variant (requires `bias` = 1 and `sampled`).
    pub lossless: bool,
    /// Generative (sampled) emission instead of production mean emission.
    pub sampled: bool,
    /// Draft-source selection: where speculative proposals come from.
    /// `"draft": "model" | "extrap" | "adaptive"` (or an object with
    /// `kind`/`period`/`eta` knobs) in the config file, `--draft` on the
    /// CLI, per-request `"draft"` override. `model` (the default) is the
    /// classic second-model setup; `extrap` drafts for free from a
    /// closed-form continuation; `adaptive` learns a residual head from
    /// verification feedback (see `specdec::draft`).
    pub draft: DraftConfig,
    /// Adaptive speculation: per-stream γ tuned online from live
    /// acceptance telemetry (`specdec::controller`). Enabled by the
    /// `"adaptive"` config key (bool or `{...}` object), `--adaptive`,
    /// or a per-request `"adaptive"` override. The server keeps one
    /// long-lived controller whose recommendation seeds each decode
    /// group, so jobs regroup as γ drifts.
    pub adaptive: bool,
    /// Controller knobs, tunable via the `"adaptive": {...}` object form.
    pub adaptive_cfg: AdaptiveConfig,
    /// Disable speculative decoding entirely (target-only AR) — the
    /// baseline mode for A/B latency comparisons.
    pub baseline: bool,
    /// KV-cached decode sessions (default on). `false` forces the
    /// stateless re-forward cost model — outputs identical, wall-clock
    /// isn't; the A/B switch behind the cached-vs-uncached bench columns.
    pub cache: bool,
    /// Worker threads for the native kernel layer's shared compute pool
    /// (row-parallel prefill matmuls + the batched-verify fan-out).
    /// 0 = auto (`STRIDE_THREADS` env, else available parallelism capped
    /// at 8). Results are bitwise identical for any value.
    pub threads: usize,
    /// Artifact directory (HLO executables, weights, manifest).
    pub artifacts: PathBuf,
    /// Base RNG seed (per-decode-group seeds are derived from it).
    pub seed: u64,
    /// Seeded fault injection (chaos testing; the `"fault"` config
    /// object). Disabled by default — serving is byte-for-byte the
    /// non-chaos path unless `fault.enabled` is set.
    pub fault: FaultConfig,
    /// Graceful-shutdown drain budget in milliseconds: how long
    /// `Server::drain` waits for queued jobs to finish (while refusing
    /// new admissions with HTTP 503) before hard shutdown.
    pub drain_ms: u64,
    /// Root directory of the content-addressed model registry (blobs +
    /// manifests). `None` derives `<artifacts>/registry` at startup.
    pub registry_dir: Option<PathBuf>,
    /// Model reference to serve at startup, resolved against the
    /// registry: `"name:version"` or `"sha256:<hex>"`. `None` keeps the
    /// seeded synthetic model pair (the pre-registry behavior).
    pub registry_model: Option<String>,
    /// Policy for adaptive draft heads and γ/k-controller state across a
    /// live weight swap (`"reset"` | `"carry"`).
    pub swap_heads: SwapHeads,
    /// HTTP request-body cap in bytes. Over-cap requests are answered
    /// with a typed 413 (`body_too_large`), never silently dropped.
    /// Registry pushes are the legitimate large-body traffic this guards.
    pub max_body_bytes: usize,
    /// Flight-recorder ring capacity in events (`--trace-capacity`,
    /// config `"trace"`). 0 (the default) disables tracing entirely: no
    /// [`crate::trace::TraceSink`] is constructed and serving is
    /// bit-identical to a build without the recorder. Nonzero
    /// preallocates the ring at startup (rounded up to a multiple of
    /// [`crate::trace::TRACE_SHARDS`]); recording never allocates or
    /// blocks — a full ring overwrites oldest and counts the drop.
    pub trace_capacity: usize,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            bind: "127.0.0.1:8080".into(),
            http_workers: 8,
            max_batch: 8,
            max_wait_ms: 2,
            replicas: 1,
            queue_cap: 256,
            sched: SchedPolicy::Edf,
            default_deadline_ms: 0,
            retry_after_ms: 1000,
            backend: "xla".into(),
            kernel: "fused".into(),
            gamma: 3,
            k: 1,
            sigma: 0.5,
            bias: 1.0,
            lossless: false,
            sampled: false,
            draft: DraftConfig::default(),
            adaptive: false,
            adaptive_cfg: AdaptiveConfig::default(),
            baseline: false,
            cache: true,
            threads: 0,
            artifacts: crate::artifacts_dir(),
            seed: 0xC0FFEE,
            fault: FaultConfig::default(),
            drain_ms: 5000,
            registry_dir: None,
            registry_model: None,
            swap_heads: SwapHeads::Reset,
            max_body_bytes: crate::http::DEFAULT_MAX_BODY_BYTES,
            trace_capacity: 0,
        }
    }
}

impl ServeConfig {
    /// Apply a JSON config object (subset of fields).
    pub fn apply_json(&mut self, j: &Json) -> Result<()> {
        let obj = j.as_obj().context("config must be a JSON object")?;
        for (k, v) in obj {
            match k.as_str() {
                "bind" => self.bind = v.as_str().context("bind")?.to_string(),
                "http_workers" => self.http_workers = v.as_usize().context("http_workers")?,
                "max_batch" => self.max_batch = v.as_usize().context("max_batch")?,
                "max_wait_ms" => self.max_wait_ms = v.as_usize().context("max_wait_ms")? as u64,
                "replicas" => self.replicas = v.as_usize().context("replicas")?,
                "queue_cap" => self.queue_cap = v.as_usize().context("queue_cap")?,
                "sched" => {
                    let s = v.as_str().context("sched")?;
                    self.sched = SchedPolicy::parse(s)
                        .with_context(|| format!("unknown sched policy '{s}' (edf|fifo)"))?;
                }
                "default_deadline_ms" => {
                    self.default_deadline_ms = v.as_usize().context("default_deadline_ms")? as u64
                }
                "retry_after_ms" => {
                    self.retry_after_ms = v.as_usize().context("retry_after_ms")? as u64
                }
                "backend" => self.backend = v.as_str().context("backend")?.to_string(),
                "kernel" => self.kernel = v.as_str().context("kernel")?.to_string(),
                "gamma" => self.gamma = v.as_usize().context("gamma")?,
                "k" => self.k = v.as_usize().context("k")?,
                "sigma" => self.sigma = v.as_f64().context("sigma")?,
                "bias" => self.bias = v.as_f64().context("bias")?,
                "lossless" => self.lossless = v.as_bool().context("lossless")?,
                "sampled" => self.sampled = v.as_bool().context("sampled")?,
                // Accepts a kind string or an object of source knobs.
                "draft" => self.apply_draft_json(v)?,
                // Accepts a bare bool or an object of controller knobs
                // (object implies enabled unless "enabled": false).
                "adaptive" => self.apply_adaptive_json(v)?,
                // Pre-controller spelling, kept as an alias.
                "adaptive_gamma" => self.adaptive = v.as_bool().context("adaptive_gamma")?,
                "baseline" => self.baseline = v.as_bool().context("baseline")?,
                "cache" => self.cache = v.as_bool().context("cache")?,
                "threads" => self.threads = v.as_usize().context("threads")?,
                "artifacts" => self.artifacts = PathBuf::from(v.as_str().context("artifacts")?),
                "seed" => self.seed = v.as_usize().context("seed")? as u64,
                // Chaos plan: an object of fault-injection knobs
                // (object implies enabled unless "enabled": false).
                "fault" => self.apply_fault_json(v)?,
                "drain_ms" => self.drain_ms = v.as_usize().context("drain_ms")? as u64,
                "registry_dir" => {
                    self.registry_dir = Some(PathBuf::from(v.as_str().context("registry_dir")?))
                }
                "registry_model" => {
                    self.registry_model = Some(v.as_str().context("registry_model")?.to_string())
                }
                "swap_heads" => {
                    let s = v.as_str().context("swap_heads")?;
                    self.swap_heads = SwapHeads::parse(s)
                        .with_context(|| format!("unknown swap_heads policy '{s}' (reset|carry)"))?;
                }
                "max_body_bytes" => {
                    self.max_body_bytes = v.as_usize().context("max_body_bytes")?
                }
                "trace" => match v {
                    // Shorthand: "trace": 4096.
                    Json::Num(_) => {
                        self.trace_capacity = v.as_usize().context("trace")?;
                    }
                    // Block form: "trace": {"capacity": 4096}.
                    Json::Obj(m) => {
                        for (tk, tv) in m {
                            match tk.as_str() {
                                "capacity" => {
                                    self.trace_capacity =
                                        tv.as_usize().context("trace.capacity")?
                                }
                                other => bail!("unknown trace config key: {other}"),
                            }
                        }
                    }
                    _ => bail!("'trace' must be a capacity number or an object"),
                },
                other => bail!("unknown config key: {other}"),
            }
        }
        Ok(())
    }

    /// Apply the `"draft"` config value: a kind string
    /// (`"model" | "extrap" | "adaptive"`) or an object of
    /// [`DraftConfig`] knobs (`kind`, `period`, `eta`).
    fn apply_draft_json(&mut self, v: &Json) -> Result<()> {
        if let Some(s) = v.as_str() {
            self.draft.kind = DraftKind::parse(s)
                .with_context(|| format!("unknown draft kind '{s}' (model|extrap|adaptive)"))?;
            return Ok(());
        }
        let obj = v.as_obj().context("'draft' must be a kind string or an object")?;
        for (k, val) in obj {
            match k.as_str() {
                "kind" => {
                    let s = val.as_str().context("draft.kind")?;
                    self.draft.kind = DraftKind::parse(s).with_context(|| {
                        format!("unknown draft kind '{s}' (model|extrap|adaptive)")
                    })?;
                }
                "period" => self.draft.period = val.as_usize().context("draft.period")?,
                "eta" => self.draft.eta = val.as_f64().context("draft.eta")?,
                other => bail!("unknown draft config key: {other}"),
            }
        }
        Ok(())
    }

    /// Apply the `"fault"` config value: an object of [`FaultConfig`]
    /// knobs. An object implies `enabled` unless it carries an explicit
    /// `"enabled": false` — writing a fault plan into a config is opting
    /// into chaos.
    fn apply_fault_json(&mut self, v: &Json) -> Result<()> {
        let obj = v.as_obj().context("'fault' must be an object of injection knobs")?;
        let f = &mut self.fault;
        f.enabled = true;
        for (k, val) in obj {
            match k.as_str() {
                "enabled" => f.enabled = val.as_bool().context("fault.enabled")?,
                "seed" => f.seed = val.as_usize().context("fault.seed")? as u64,
                "p_panic" => f.p_panic = val.as_f64().context("fault.p_panic")?,
                "p_stall" => f.p_stall = val.as_f64().context("fault.p_stall")?,
                "stall_ms" => f.stall_ms = val.as_usize().context("fault.stall_ms")? as u64,
                "p_nan" => f.p_nan = val.as_f64().context("fault.p_nan")?,
                "p_blob_corrupt" => {
                    f.p_blob_corrupt = val.as_f64().context("fault.p_blob_corrupt")?
                }
                "max_faults" => f.max_faults = val.as_usize().context("fault.max_faults")? as u64,
                other => bail!("unknown fault config key: {other}"),
            }
        }
        Ok(())
    }

    /// Apply the `"adaptive"` config value: `true`/`false`, or an object
    /// of [`AdaptiveConfig`] knobs (which implies `enabled` unless an
    /// explicit `"enabled": false` is present).
    fn apply_adaptive_json(&mut self, v: &Json) -> Result<()> {
        if let Some(b) = v.as_bool() {
            self.adaptive = b;
            return Ok(());
        }
        let obj = v.as_obj().context("'adaptive' must be a bool or an object")?;
        self.adaptive = true;
        let a = &mut self.adaptive_cfg;
        for (k, val) in obj {
            match k.as_str() {
                "enabled" => self.adaptive = val.as_bool().context("adaptive.enabled")?,
                "min_gamma" => a.min_gamma = val.as_usize().context("adaptive.min_gamma")?,
                "max_gamma" => a.max_gamma = val.as_usize().context("adaptive.max_gamma")?,
                "halflife" => a.halflife = val.as_f64().context("adaptive.halflife")?,
                "alpha0" => a.alpha0 = val.as_f64().context("adaptive.alpha0")?,
                "warmup" => a.warmup = val.as_usize().context("adaptive.warmup")?,
                "dwell" => a.dwell = val.as_usize().context("adaptive.dwell")?,
                "hysteresis" => a.hysteresis = val.as_f64().context("adaptive.hysteresis")?,
                "c_override" => a.c_override = val.as_f64().context("adaptive.c_override")?,
                "sigma_adapt" => a.sigma_adapt = val.as_bool().context("adaptive.sigma_adapt")?,
                "sigma_min" => a.sigma_min = val.as_f64().context("adaptive.sigma_min")?,
                "sigma_max" => a.sigma_max = val.as_f64().context("adaptive.sigma_max")?,
                "alpha_lo" => a.alpha_lo = val.as_f64().context("adaptive.alpha_lo")?,
                "alpha_hi" => a.alpha_hi = val.as_f64().context("adaptive.alpha_hi")?,
                "sigma_step" => a.sigma_step = val.as_f64().context("adaptive.sigma_step")?,
                "k_max" => a.k_max = val.as_usize().context("adaptive.k_max")?,
                "breaker" => a.breaker = val.as_bool().context("adaptive.breaker")?,
                "breaker_alpha_floor" => {
                    a.breaker_alpha_floor = val.as_f64().context("adaptive.breaker_alpha_floor")?
                }
                "breaker_trip_rounds" => {
                    a.breaker_trip_rounds = val.as_usize().context("adaptive.breaker_trip_rounds")?
                }
                "breaker_nf_trip" => {
                    a.breaker_nf_trip = val.as_usize().context("adaptive.breaker_nf_trip")?
                }
                "breaker_cooldown" => {
                    a.breaker_cooldown = val.as_usize().context("adaptive.breaker_cooldown")?
                }
                "breaker_probes" => {
                    a.breaker_probes = val.as_usize().context("adaptive.breaker_probes")?
                }
                other => bail!("unknown adaptive config key: {other}"),
            }
        }
        Ok(())
    }

    /// Apply CLI overrides.
    pub fn apply_cli(&mut self, cli: &Cli) -> Result<()> {
        if let Some(path) = cli.get("config") {
            let text = std::fs::read_to_string(path)
                .with_context(|| format!("reading config {path}"))?;
            self.apply_json(&Json::parse(&text)?)?;
        }
        if let Some(v) = cli.get("bind") {
            self.bind = v.to_string();
        }
        if let Some(v) = cli.get_usize("http-workers")? {
            self.http_workers = v;
        }
        if let Some(v) = cli.get_usize("max-batch")? {
            self.max_batch = v;
        }
        if let Some(v) = cli.get_usize("max-wait-ms")? {
            self.max_wait_ms = v as u64;
        }
        if let Some(v) = cli.get_usize("replicas")? {
            self.replicas = v;
        }
        if let Some(v) = cli.get_usize("queue-cap")? {
            self.queue_cap = v;
        }
        if let Some(v) = cli.get("sched") {
            self.sched = SchedPolicy::parse(v)
                .with_context(|| format!("--sched must be edf|fifo, got '{v}'"))?;
        }
        if let Some(v) = cli.get_usize("default-deadline-ms")? {
            self.default_deadline_ms = v as u64;
        }
        if let Some(v) = cli.get_usize("retry-after-ms")? {
            self.retry_after_ms = v as u64;
        }
        if let Some(v) = cli.get("backend") {
            self.backend = v.to_string();
        }
        if let Some(v) = cli.get("kernel") {
            self.kernel = v.to_string();
        }
        if let Some(v) = cli.get_usize("gamma")? {
            self.gamma = v;
        }
        if let Some(v) = cli.get_usize("k")? {
            self.k = v;
        }
        if let Some(v) = cli.get_f64("sigma")? {
            self.sigma = v;
        }
        if let Some(v) = cli.get_f64("bias")? {
            self.bias = v;
        }
        if cli.flag("lossless") {
            self.lossless = true;
        }
        if cli.flag("sampled") {
            self.sampled = true;
        }
        if let Some(v) = cli.get("draft") {
            self.draft.kind = DraftKind::parse(v)
                .with_context(|| format!("--draft must be model|extrap|adaptive, got '{v}'"))?;
        }
        if let Some(v) = cli.get_usize("draft-period")? {
            self.draft.period = v;
        }
        if let Some(v) = cli.get_f64("draft-eta")? {
            self.draft.eta = v;
        }
        // `--adaptive` enables the controller; `--adaptive-gamma` is the
        // pre-controller spelling, kept as an alias.
        if cli.flag("adaptive") || cli.flag("adaptive-gamma") {
            self.adaptive = true;
        }
        if cli.flag("baseline") {
            self.baseline = true;
        }
        // `--no-cache` switches to the stateless cost model; `--cache`
        // re-enables it (later flag wins when both are given via file+CLI).
        if cli.flag("no-cache") {
            self.cache = false;
        } else if cli.flag("cache") {
            self.cache = true;
        }
        if let Some(v) = cli.get_usize("threads")? {
            self.threads = v;
        }
        if let Some(v) = cli.get("artifacts") {
            self.artifacts = PathBuf::from(v);
        }
        if let Some(v) = cli.get_usize("seed")? {
            self.seed = v as u64;
        }
        if let Some(v) = cli.get_usize("drain-ms")? {
            self.drain_ms = v as u64;
        }
        if let Some(v) = cli.get("registry-dir") {
            self.registry_dir = Some(PathBuf::from(v));
        }
        if let Some(v) = cli.get("registry-model") {
            self.registry_model = Some(v.to_string());
        }
        if let Some(v) = cli.get("swap-heads") {
            self.swap_heads = SwapHeads::parse(v)
                .with_context(|| format!("--swap-heads must be reset|carry, got '{v}'"))?;
        }
        if let Some(v) = cli.get_usize("max-body-bytes")? {
            self.max_body_bytes = v;
        }
        if let Some(v) = cli.get_usize("trace-capacity")? {
            self.trace_capacity = v;
        }
        self.validate()
    }

    /// Root directory of the model registry: the configured
    /// `registry_dir`, or `<artifacts>/registry` when unset.
    pub fn registry_root(&self) -> PathBuf {
        self.registry_dir.clone().unwrap_or_else(|| self.artifacts.join("registry"))
    }

    /// Check cross-field invariants (γ bounds, σ/λ positivity, variant
    /// compatibility, backend/kernel names, adaptive knobs).
    pub fn validate(&self) -> Result<()> {
        if self.gamma == 0 || self.gamma > 64 {
            bail!("gamma must be in [1, 64], got {}", self.gamma);
        }
        if self.k == 0 || self.k > crate::specdec::MAX_TREE_K {
            bail!("k must be in [1, {}], got {}", crate::specdec::MAX_TREE_K, self.k);
        }
        if self.lossless && self.k > 1 {
            bail!(
                "lossless requires k = 1: tree speculation's exactness is only \
                 proven for decodes bit-identical to the single-trajectory path"
            );
        }
        if self.lossless && self.adaptive && self.adaptive_cfg.k_max > 1 {
            bail!(
                "lossless requires adaptive.k_max = 1: the controller may not \
                 branch a decode whose output law must stay exactly p"
            );
        }
        if !(self.sigma > 0.0) {
            bail!("sigma must be positive");
        }
        if !(self.bias > 0.0) {
            bail!("bias must be positive");
        }
        if self.lossless && (self.bias - 1.0).abs() > 1e-12 {
            bail!("lossless requires bias = 1 (canonical acceptance)");
        }
        if self.lossless && !self.sampled {
            bail!("lossless requires --sampled emission (Theorems 1-2 are about the sampled chain)");
        }
        if !matches!(self.backend.as_str(), "xla" | "native") {
            bail!("backend must be 'xla' or 'native'");
        }
        if self.replicas == 0 || self.replicas > 64 {
            bail!("replicas must be in [1, 64], got {}", self.replicas);
        }
        if self.backend == "xla" && self.replicas > 1 {
            bail!(
                "replicas > 1 requires the native backend: PJRT client state \
                 is not shareable across engine threads (xla replicas = 1)"
            );
        }
        if self.queue_cap == 0 {
            bail!("queue_cap must be >= 1");
        }
        if self.retry_after_ms == 0 {
            bail!("retry_after_ms must be >= 1");
        }
        if !matches!(self.kernel.as_str(), "fused" | "pallas") {
            bail!("kernel must be 'fused' or 'pallas'");
        }
        if self.max_body_bytes < 1024 {
            bail!(
                "max_body_bytes must be >= 1024 (a cap below one KiB rejects \
                 every real request), got {}",
                self.max_body_bytes
            );
        }
        self.draft.validate()?;
        // Bounds hold whether or not chaos is armed — a config file
        // carrying a nonsense plan is wrong even with enabled: false.
        self.fault.validate()?;
        if self.adaptive {
            self.adaptive_cfg.validate()?;
            if self.adaptive_cfg.sigma_adapt {
                bail!(
                    "adaptive.sigma_adapt is single-stream only; the server's \
                     batched decode groups share one acceptance policy"
                );
            }
        }
        Ok(())
    }

    /// Lower this serving configuration into the decode engine's
    /// [`SpecConfig`] (the per-decode-group view of the same knobs).
    pub fn spec_config(&self) -> SpecConfig {
        SpecConfig {
            gamma: self.gamma,
            k: self.k,
            policy: AcceptancePolicy::new(self.sigma, self.bias),
            variant: if self.lossless { Variant::Lossless } else { Variant::Practical },
            seed: self.seed,
            max_residual_draws: 10_000,
            emission: if self.sampled { Emission::Sampled } else { Emission::Mean },
            cache: if self.cache { CacheMode::On } else { CacheMode::Off },
            draft: self.draft,
            adaptive: if self.adaptive { Some(self.adaptive_cfg) } else { None },
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args(s: &str) -> Vec<String> {
        s.split_whitespace().map(String::from).collect()
    }

    #[test]
    fn cli_parsing() {
        let c = Cli::parse(args("serve --gamma 5 --sigma=0.7 --lossless --bind 0.0.0.0:9")).unwrap();
        assert_eq!(c.positional, vec!["serve"]);
        assert_eq!(c.get("gamma"), Some("5"));
        assert_eq!(c.get("sigma"), Some("0.7"));
        assert!(c.flag("lossless"));
        assert_eq!(c.get("bind"), Some("0.0.0.0:9"));
    }

    #[test]
    fn config_precedence_and_validation() {
        let mut cfg = ServeConfig::default();
        cfg.apply_json(&Json::parse(r#"{"gamma": 7, "sigma": 0.35}"#).unwrap()).unwrap();
        assert_eq!(cfg.gamma, 7);
        let cli = Cli::parse(args("--gamma 2")).unwrap();
        cfg.apply_cli(&cli).unwrap();
        assert_eq!(cfg.gamma, 2);
        assert!((cfg.sigma - 0.35).abs() < 1e-12);
    }

    #[test]
    fn rejects_bad_values() {
        let mut cfg = ServeConfig::default();
        assert!(cfg.apply_json(&Json::parse(r#"{"gamma": 0}"#).unwrap()).is_ok());
        assert!(cfg.validate().is_err()); // gamma 0 invalid
        let mut cfg = ServeConfig::default();
        assert!(cfg.apply_json(&Json::parse(r#"{"nope": 1}"#).unwrap()).is_err());
        let mut cfg = ServeConfig::default();
        cfg.lossless = true;
        cfg.bias = 1.5;
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn spec_config_mapping() {
        let mut cfg = ServeConfig::default();
        cfg.gamma = 4;
        cfg.sigma = 0.6;
        let sc = cfg.spec_config();
        assert_eq!(sc.gamma, 4);
        assert_eq!(sc.emission, Emission::Mean);
        assert!((sc.policy.sigma - 0.6).abs() < 1e-12);
        assert_eq!(sc.cache, CacheMode::On);
    }

    #[test]
    fn threads_plumbing() {
        let mut cfg = ServeConfig::default();
        assert_eq!(cfg.threads, 0, "default must be auto");
        cfg.apply_json(&Json::parse(r#"{"threads": 4}"#).unwrap()).unwrap();
        assert_eq!(cfg.threads, 4);
        let cli = Cli::parse(args("--threads 2")).unwrap();
        cfg.apply_cli(&cli).unwrap();
        assert_eq!(cfg.threads, 2);
    }

    #[test]
    fn adaptive_plumbing() {
        // Bool form.
        let mut cfg = ServeConfig::default();
        assert!(!cfg.adaptive);
        cfg.apply_json(&Json::parse(r#"{"adaptive": true}"#).unwrap()).unwrap();
        assert!(cfg.adaptive);
        assert!(cfg.spec_config().adaptive.is_some());
        cfg.apply_json(&Json::parse(r#"{"adaptive": false}"#).unwrap()).unwrap();
        assert!(cfg.spec_config().adaptive.is_none());

        // Object form implies enabled and sets knobs.
        let mut cfg = ServeConfig::default();
        cfg.apply_json(
            &Json::parse(r#"{"adaptive": {"max_gamma": 8, "dwell": 2, "hysteresis": 0.05}}"#)
                .unwrap(),
        )
        .unwrap();
        assert!(cfg.adaptive);
        assert_eq!(cfg.adaptive_cfg.max_gamma, 8);
        assert_eq!(cfg.adaptive_cfg.dwell, 2);
        assert!((cfg.adaptive_cfg.hysteresis - 0.05).abs() < 1e-12);
        cfg.validate().unwrap();

        // Explicit enabled: false in the object form.
        let mut cfg = ServeConfig::default();
        cfg.apply_json(&Json::parse(r#"{"adaptive": {"enabled": false, "max_gamma": 4}}"#).unwrap())
            .unwrap();
        assert!(!cfg.adaptive);
        assert_eq!(cfg.adaptive_cfg.max_gamma, 4, "knobs apply even when disabled");

        // Unknown knob rejected.
        let mut cfg = ServeConfig::default();
        assert!(cfg.apply_json(&Json::parse(r#"{"adaptive": {"nope": 1}}"#).unwrap()).is_err());

        // CLI flag and the pre-controller alias.
        let mut cfg = ServeConfig::default();
        cfg.apply_cli(&Cli::parse(args("--adaptive")).unwrap()).unwrap();
        assert!(cfg.adaptive);
        let mut cfg = ServeConfig::default();
        cfg.apply_cli(&Cli::parse(args("--adaptive-gamma")).unwrap()).unwrap();
        assert!(cfg.adaptive);

        // Bad bounds rejected at validation.
        let mut cfg = ServeConfig::default();
        cfg.apply_json(&Json::parse(r#"{"adaptive": {"min_gamma": 9, "max_gamma": 2}}"#).unwrap())
            .unwrap();
        assert!(cfg.validate().is_err());

        // sigma adaptation is single-stream only; the server rejects it.
        let mut cfg = ServeConfig::default();
        cfg.apply_json(&Json::parse(r#"{"adaptive": {"sigma_adapt": true}}"#).unwrap()).unwrap();
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn k_plumbing() {
        // Default is the classic single-trajectory engine.
        let mut cfg = ServeConfig::default();
        assert_eq!(cfg.k, 1);
        assert_eq!(cfg.spec_config().k, 1);

        // JSON and CLI forms, CLI winning.
        cfg.apply_json(&Json::parse(r#"{"k": 4}"#).unwrap()).unwrap();
        assert_eq!(cfg.k, 4);
        assert_eq!(cfg.spec_config().k, 4);
        cfg.apply_cli(&Cli::parse(args("--k 2")).unwrap()).unwrap();
        assert_eq!(cfg.k, 2);

        // Bounds: 0 and > MAX_TREE_K rejected at validation.
        let mut cfg = ServeConfig::default();
        cfg.k = 0;
        assert!(cfg.validate().is_err());
        cfg.k = crate::specdec::MAX_TREE_K + 1;
        assert!(cfg.validate().is_err());
        cfg.k = crate::specdec::MAX_TREE_K;
        cfg.validate().unwrap();

        // Lossless refuses trees, both static k and the adaptive k axis.
        let mut cfg = ServeConfig::default();
        cfg.lossless = true;
        cfg.sampled = true;
        cfg.k = 2;
        assert!(cfg.validate().is_err());
        cfg.k = 1;
        cfg.validate().unwrap();
        cfg.adaptive = true;
        cfg.adaptive_cfg.k_max = 4;
        assert!(cfg.validate().is_err());
        cfg.adaptive_cfg.k_max = 1;
        cfg.validate().unwrap();

        // The adaptive object form carries the k_max knob.
        let mut cfg = ServeConfig::default();
        cfg.apply_json(&Json::parse(r#"{"adaptive": {"k_max": 4}}"#).unwrap()).unwrap();
        assert!(cfg.adaptive);
        assert_eq!(cfg.adaptive_cfg.k_max, 4);
        cfg.validate().unwrap();
        cfg.apply_json(&Json::parse(r#"{"adaptive": {"k_max": 99}}"#).unwrap()).unwrap();
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn draft_plumbing() {
        // String form.
        let mut cfg = ServeConfig::default();
        assert_eq!(cfg.draft.kind, DraftKind::Model);
        cfg.apply_json(&Json::parse(r#"{"draft": "extrap"}"#).unwrap()).unwrap();
        assert_eq!(cfg.draft.kind, DraftKind::Extrap);
        assert_eq!(cfg.spec_config().draft.kind, DraftKind::Extrap);

        // Object form sets knobs.
        let mut cfg = ServeConfig::default();
        cfg.apply_json(
            &Json::parse(r#"{"draft": {"kind": "adaptive", "eta": 0.3, "period": 24}}"#).unwrap(),
        )
        .unwrap();
        assert_eq!(cfg.draft.kind, DraftKind::Adaptive);
        assert!((cfg.draft.eta - 0.3).abs() < 1e-12);
        assert_eq!(cfg.draft.period, 24);
        cfg.validate().unwrap();

        // Unknown kind / unknown knob rejected.
        let mut cfg = ServeConfig::default();
        assert!(cfg.apply_json(&Json::parse(r#"{"draft": "warp"}"#).unwrap()).is_err());
        assert!(cfg.apply_json(&Json::parse(r#"{"draft": {"nope": 1}}"#).unwrap()).is_err());

        // CLI flag.
        let mut cfg = ServeConfig::default();
        cfg.apply_cli(&Cli::parse(args("--draft adaptive --draft-eta 0.8")).unwrap()).unwrap();
        assert_eq!(cfg.draft.kind, DraftKind::Adaptive);
        assert!((cfg.draft.eta - 0.8).abs() < 1e-12);
        let mut cfg = ServeConfig::default();
        assert!(cfg.apply_cli(&Cli::parse(args("--draft warp")).unwrap()).is_err());

        // Bad eta rejected at validation.
        let mut cfg = ServeConfig::default();
        cfg.apply_json(&Json::parse(r#"{"draft": {"eta": 5.0}}"#).unwrap()).unwrap();
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn scheduler_plumbing() {
        // Defaults.
        let cfg = ServeConfig::default();
        assert_eq!(cfg.replicas, 1);
        assert_eq!(cfg.queue_cap, 256);
        assert_eq!(cfg.sched, SchedPolicy::Edf);
        assert_eq!(cfg.default_deadline_ms, 0);
        assert_eq!(cfg.retry_after_ms, 1000);

        // JSON form.
        let mut cfg = ServeConfig::default();
        cfg.apply_json(
            &Json::parse(
                r#"{"replicas": 4, "queue_cap": 32, "sched": "fifo",
                    "default_deadline_ms": 500, "retry_after_ms": 250,
                    "backend": "native"}"#,
            )
            .unwrap(),
        )
        .unwrap();
        assert_eq!(cfg.replicas, 4);
        assert_eq!(cfg.queue_cap, 32);
        assert_eq!(cfg.sched, SchedPolicy::Fifo);
        assert_eq!(cfg.default_deadline_ms, 500);
        assert_eq!(cfg.retry_after_ms, 250);
        cfg.validate().unwrap();

        // CLI form.
        let mut cfg = ServeConfig::default();
        cfg.apply_cli(
            &Cli::parse(args("--backend native --replicas 2 --queue-cap 8 --sched edf")).unwrap(),
        )
        .unwrap();
        assert_eq!(cfg.replicas, 2);
        assert_eq!(cfg.queue_cap, 8);
        assert_eq!(cfg.sched, SchedPolicy::Edf);

        // Bad values.
        let mut cfg = ServeConfig::default();
        assert!(cfg.apply_json(&Json::parse(r#"{"sched": "lifo"}"#).unwrap()).is_err());
        let mut cfg = ServeConfig::default();
        cfg.backend = "native".into();
        cfg.replicas = 0;
        assert!(cfg.validate().is_err());
        cfg.replicas = 65;
        assert!(cfg.validate().is_err());
        cfg.replicas = 2;
        cfg.queue_cap = 0;
        assert!(cfg.validate().is_err());

        // PJRT state is not shareable: xla + replicas > 1 is rejected.
        let mut cfg = ServeConfig::default();
        cfg.replicas = 2; // backend defaults to xla
        assert!(cfg.validate().is_err());
        cfg.backend = "native".into();
        cfg.validate().unwrap();

        // Policy names roundtrip.
        for p in [SchedPolicy::Edf, SchedPolicy::Fifo] {
            assert_eq!(SchedPolicy::parse(p.as_str()), Some(p));
        }
        assert_eq!(SchedPolicy::parse("lifo"), None);
    }

    #[test]
    fn fault_and_drain_plumbing() {
        // Defaults: chaos off, a real drain budget.
        let cfg = ServeConfig::default();
        assert!(!cfg.fault.enabled);
        assert_eq!(cfg.drain_ms, 5000);
        cfg.validate().unwrap();

        // Object form implies enabled and sets knobs.
        let mut cfg = ServeConfig::default();
        cfg.apply_json(
            &Json::parse(
                r#"{"fault": {"seed": 9, "p_panic": 0.01, "p_nan": 0.05,
                    "stall_ms": 10, "max_faults": 40}, "drain_ms": 250}"#,
            )
            .unwrap(),
        )
        .unwrap();
        assert!(cfg.fault.enabled);
        assert_eq!(cfg.fault.seed, 9);
        assert!((cfg.fault.p_panic - 0.01).abs() < 1e-12);
        assert_eq!(cfg.fault.max_faults, 40);
        assert_eq!(cfg.drain_ms, 250);
        cfg.validate().unwrap();

        // Explicit enabled: false keeps the knobs but disarms the plan.
        let mut cfg = ServeConfig::default();
        cfg.apply_json(&Json::parse(r#"{"fault": {"enabled": false, "p_nan": 0.5}}"#).unwrap())
            .unwrap();
        assert!(!cfg.fault.enabled);
        assert!((cfg.fault.p_nan - 0.5).abs() < 1e-12);

        // Unknown knob and out-of-bounds values are rejected.
        let mut cfg = ServeConfig::default();
        assert!(cfg.apply_json(&Json::parse(r#"{"fault": {"nope": 1}}"#).unwrap()).is_err());
        let mut cfg = ServeConfig::default();
        cfg.apply_json(&Json::parse(r#"{"fault": {"p_panic": 0.9, "p_nan": 0.9}}"#).unwrap())
            .unwrap();
        assert!(cfg.validate().is_err(), "probabilities must form a sub-distribution");

        // CLI drain override.
        let mut cfg = ServeConfig::default();
        cfg.apply_cli(&Cli::parse(args("--drain-ms 750")).unwrap()).unwrap();
        assert_eq!(cfg.drain_ms, 750);

        // Breaker knobs ride the adaptive object (and imply adaptive).
        let mut cfg = ServeConfig::default();
        cfg.apply_json(
            &Json::parse(
                r#"{"adaptive": {"breaker": true, "breaker_alpha_floor": 0.2,
                    "breaker_trip_rounds": 4, "breaker_nf_trip": 3,
                    "breaker_cooldown": 16, "breaker_probes": 2}}"#,
            )
            .unwrap(),
        )
        .unwrap();
        assert!(cfg.adaptive);
        assert!(cfg.adaptive_cfg.breaker);
        assert!((cfg.adaptive_cfg.breaker_alpha_floor - 0.2).abs() < 1e-12);
        assert_eq!(cfg.adaptive_cfg.breaker_trip_rounds, 4);
        assert_eq!(cfg.adaptive_cfg.breaker_nf_trip, 3);
        assert_eq!(cfg.adaptive_cfg.breaker_cooldown, 16);
        assert_eq!(cfg.adaptive_cfg.breaker_probes, 2);
        cfg.validate().unwrap();
        // Breaker bounds are enforced when armed.
        cfg.adaptive_cfg.breaker_alpha_floor = 1.5;
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn registry_plumbing() {
        // Defaults: no registry model, registry root derives from artifacts.
        let cfg = ServeConfig::default();
        assert!(cfg.registry_dir.is_none());
        assert!(cfg.registry_model.is_none());
        assert_eq!(cfg.swap_heads, SwapHeads::Reset);
        assert_eq!(cfg.max_body_bytes, crate::http::DEFAULT_MAX_BODY_BYTES);
        assert_eq!(cfg.registry_root(), cfg.artifacts.join("registry"));

        // JSON form.
        let mut cfg = ServeConfig::default();
        cfg.apply_json(
            &Json::parse(
                r#"{"registry_dir": "/tmp/reg", "registry_model": "demo:v1",
                    "swap_heads": "carry", "max_body_bytes": 1048576}"#,
            )
            .unwrap(),
        )
        .unwrap();
        assert_eq!(cfg.registry_root(), PathBuf::from("/tmp/reg"));
        assert_eq!(cfg.registry_model.as_deref(), Some("demo:v1"));
        assert_eq!(cfg.swap_heads, SwapHeads::Carry);
        assert_eq!(cfg.max_body_bytes, 1 << 20);
        cfg.validate().unwrap();

        // CLI form wins.
        cfg.apply_cli(
            &Cli::parse(args(
                "--registry-dir /tmp/reg2 --registry-model demo:v2 \
                 --swap-heads reset --max-body-bytes 2048",
            ))
            .unwrap(),
        )
        .unwrap();
        assert_eq!(cfg.registry_root(), PathBuf::from("/tmp/reg2"));
        assert_eq!(cfg.registry_model.as_deref(), Some("demo:v2"));
        assert_eq!(cfg.swap_heads, SwapHeads::Reset);
        assert_eq!(cfg.max_body_bytes, 2048);

        // Bad values.
        let mut cfg = ServeConfig::default();
        assert!(cfg.apply_json(&Json::parse(r#"{"swap_heads": "merge"}"#).unwrap()).is_err());
        let mut cfg = ServeConfig::default();
        cfg.max_body_bytes = 16;
        assert!(cfg.validate().is_err(), "sub-KiB body cap must be rejected");

        // Policy names roundtrip.
        for p in [SwapHeads::Reset, SwapHeads::Carry] {
            assert_eq!(SwapHeads::parse(p.as_str()), Some(p));
        }
        assert_eq!(SwapHeads::parse("merge"), None);

        // Blob-corruption knob rides the fault object.
        let mut cfg = ServeConfig::default();
        cfg.apply_json(
            &Json::parse(r#"{"fault": {"p_blob_corrupt": 0.25, "max_faults": 5}}"#).unwrap(),
        )
        .unwrap();
        assert!(cfg.fault.enabled);
        assert!((cfg.fault.p_blob_corrupt - 0.25).abs() < 1e-12);
        cfg.validate().unwrap();
        cfg.fault.p_blob_corrupt = 1.5;
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn trace_plumbing() {
        // Default: tracing off.
        let cfg = ServeConfig::default();
        assert_eq!(cfg.trace_capacity, 0);

        // JSON shorthand and block forms.
        let mut cfg = ServeConfig::default();
        cfg.apply_json(&Json::parse(r#"{"trace": 4096}"#).unwrap()).unwrap();
        assert_eq!(cfg.trace_capacity, 4096);
        let mut cfg = ServeConfig::default();
        cfg.apply_json(&Json::parse(r#"{"trace": {"capacity": 512}}"#).unwrap()).unwrap();
        assert_eq!(cfg.trace_capacity, 512);
        cfg.validate().unwrap();

        // CLI form wins.
        cfg.apply_cli(&Cli::parse(args("--trace-capacity 1024")).unwrap()).unwrap();
        assert_eq!(cfg.trace_capacity, 1024);

        // Bad values.
        let mut cfg = ServeConfig::default();
        assert!(cfg.apply_json(&Json::parse(r#"{"trace": "big"}"#).unwrap()).is_err());
        let mut cfg = ServeConfig::default();
        assert!(cfg
            .apply_json(&Json::parse(r#"{"trace": {"slots": 4}}"#).unwrap())
            .is_err());
    }

    #[test]
    fn cache_toggle_plumbing() {
        let mut cfg = ServeConfig::default();
        assert!(cfg.cache);
        cfg.apply_json(&Json::parse(r#"{"cache": false}"#).unwrap()).unwrap();
        assert!(!cfg.cache);
        assert_eq!(cfg.spec_config().cache, CacheMode::Off);
        let cli = Cli::parse(args("--cache")).unwrap();
        cfg.apply_cli(&cli).unwrap();
        assert!(cfg.cache);
        let cli = Cli::parse(args("--no-cache")).unwrap();
        cfg.apply_cli(&cli).unwrap();
        assert!(!cfg.cache);
    }
}
