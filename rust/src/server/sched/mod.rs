//! The serving scheduler: bounded admission, deadline/priority-aware
//! dispatch, and an engine replica pool.
//!
//! This subsystem replaces the original single-FIFO-batcher serving loop
//! (one engine thread draining an unbounded channel in arrival order)
//! with a production-shaped pipeline:
//!
//! ```text
//!  HTTP workers ── admit ──► AdmissionQueue ── next_batch ──► replica 0
//!        │   (bounded; shed 429 /   │  (EDF within group,     replica 1
//!        │    priority eviction)    │   affinity + stealing)     ...
//!        ▼                          ▼                         replica N-1
//!   ServeError::Shed        ServeError::DeadlineExpired    (own model/session
//!   + Retry-After           (expired jobs never decode)     stacks over one
//!                                                           Arc'd weight store)
//! ```
//!
//! * **Admission** ([`AdmissionQueue`]): a hard queue-depth cap from
//!   `ServeConfig::queue_cap`. At the cap, arrivals are shed with HTTP
//!   429 + `Retry-After`; under the default [`SchedPolicy::Edf`] a
//!   higher-priority arrival instead evicts the worst queued job. Jobs
//!   whose `deadline_ms` elapses while queued are failed fast with a
//!   distinct error code (HTTP 504) and are **never decoded**.
//! * **Dispatch order**: jobs are keyed by their decode-compatibility
//!   group ([`GroupKey`] — the (γ, σ, cache, adaptive, draft-kind)
//!   tuple), and within a group ordered by priority band first, then
//!   earliest deadline, then arrival. [`SchedPolicy::Fifo`] preserves
//!   pure arrival order as the A/B baseline.
//! * **Replicas** ([`start_pool`]): N independent engine stacks — on the
//!   native backend each replica's models share one `Arc`-packed weight
//!   storage ([`crate::models::NativeBackend::replicate`]) — each
//!   running its own drain loop. Replicas prefer groups they served
//!   last (affinity) and steal the most urgent other group when idle,
//!   so one slow group cannot head-of-line-block the fleet. Learned
//!   draft heads and the adaptive-γ controller are shared behind
//!   mutexes and merged across replicas.
//! * **Determinism**: decode groups run through
//!   [`crate::specdec::sd_generate_stream_seeded`] with one seed per
//!   request, so a response is a pure function of the request — bit
//!   identical to `sd_generate_from` at that seed for *any* replica
//!   count, batch composition, or arrival order
//!   (`benches/serving_load.rs` pins this).
//!
//! Observability: `stride_queue_depth`, `stride_sheds_total`,
//! `stride_expired_total`, `stride_steals`, per-replica batch counters,
//! per-priority latency histograms, and per-priority SLO-attainment
//! gauges — all rendered at `/metrics` and summarized in the `/stats`
//! `"scheduler"` block. `/healthz` turns into a readiness probe:
//! it reports HTTP 503 with `"ready": false` while the admission queue
//! is saturated, so external load balancers can drain a hot replica.
//!
//! Live weight swap rides the same machinery: [`ModelSlot`] holds the
//! pool's current [`ReplicaBuilder`] + model identity behind a
//! generation counter, [`AdmissionQueue::bump_epoch`] wakes parked
//! replicas ([`NextBatch::Interrupted`]), and each replica rebinds
//! between batches — queued jobs are untouched, so a swap drops zero
//! requests.

mod queue;
mod pool;

pub use pool::{start_pool, ModelSlot, ReplicaBuilder, ReplicaStacks, SchedShared};
pub use queue::{AdmissionQueue, GroupKey, NextBatch, QueuedJob};

pub use crate::config::SchedPolicy;

/// The model geometry the executor needs for request validation and
/// context clamping — the manifest fields the scheduler actually uses,
/// decoupled from [`crate::runtime::Manifest`] so tests and benches can
/// run the full serving stack over synthetic models with no artifacts
/// on disk.
#[derive(Clone, Copy, Debug)]
pub struct ModelShape {
    /// Values per patch token.
    pub patch: usize,
    /// Maximum context length in patches.
    pub n_ctx: usize,
}
