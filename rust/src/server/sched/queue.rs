//! Bounded, priority/deadline-aware admission queue with per-group EDF
//! ordering — the data structure between the HTTP workers and the
//! replica pool.
//!
//! Invariants:
//! * Depth never exceeds the cap: at the cap an arrival is shed (or,
//!   under EDF, the *worst* queued job is evicted for a strictly
//!   higher-priority arrival) — every removal answers its reply channel
//!   with a typed [`ServeError`].
//! * Expired jobs never reach a replica: both `admit` and `next_batch`
//!   purge deadline-expired entries first, failing them fast with
//!   [`ServeError::DeadlineExpired`].
//! * Within a group, `next_batch` hands out jobs in dispatch order:
//!   priority band desc, deadline asc (absent = infinitely far), arrival
//!   seq asc under [`SchedPolicy::Edf`]; pure arrival seq under
//!   [`SchedPolicy::Fifo`].
//! * A job is requeued **at most once**: when a replica panics mid-group,
//!   the supervisor puts innocent group-mates back via [`AdmissionQueue::
//!   requeue`] (cap-exempt — they were already admitted once), and the
//!   `requeued` flag makes a second failure terminal.
//! * Draining ([`AdmissionQueue::begin_drain`]) refuses new admissions
//!   with [`ServeError::Draining`] while replicas keep dispatching the
//!   backlog — graceful shutdown empties the queue before stopping.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering as AtomicOrdering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use crate::config::SchedPolicy;
use crate::metrics::Metrics;
use crate::specdec::DraftKind;
use crate::trace::{EventKind, TraceSink};

use super::super::batcher::Job;
use super::super::protocol::{Priority, ServeError};

/// Decode-compatibility key: jobs with equal keys can share one lockstep
/// decode group (one session pool, one draft source, one cost model).
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum GroupKey {
    /// A speculative-decode group — the (γ, k, σ, cache, adaptive,
    /// draft-kind) tuple the batcher groups by.
    Sd {
        /// Draft block length γ (the live controller's current value for
        /// adaptive jobs, so they regroup as γ drifts).
        gamma: usize,
        /// Tree branch count k (the controller's current value for
        /// adaptive jobs). k = 1 groups run the lockstep batched engine;
        /// k > 1 groups decode per-job through the tree engine, so
        /// grouping by k keeps the two execution shapes from mixing.
        k: usize,
        /// Acceptance width σ as stable bits (f64 keys can't derive Ord).
        sigma_bits: u64,
        /// KV-cache on/off.
        cache: bool,
        /// Riding the server's long-lived γ controller.
        adaptive: bool,
        /// Proposal source kind.
        kind: DraftKind,
    },
    /// Individually-executed jobs (baseline/draft-only AR modes); they
    /// still queue, order, and shed like everything else.
    Single,
}

/// One admitted request waiting for (or handed to) a replica.
pub struct QueuedJob {
    /// The job itself (request + reply channel).
    pub job: Job,
    /// Scheduling band.
    pub priority: Priority,
    /// Absolute expiry instant, when the request carries a deadline.
    pub deadline: Option<Instant>,
    /// The deadline in milliseconds as admitted (SLO accounting).
    pub deadline_ms: Option<u64>,
    /// Admission sequence number (arrival-order tiebreak).
    pub seq: u64,
    /// True once this job has been put back after a replica failure.
    /// The requeue-once policy: a second failure answers the job with
    /// [`ServeError::ReplicaFailure`] instead of requeuing again, so a
    /// poison request cannot crash replicas forever.
    pub requeued: bool,
}

impl QueuedJob {
    /// Dispatch-order key under EDF: smaller sorts first. Priority band
    /// desc, then deadline asc (absent = infinitely far), then arrival.
    fn edf_key(&self) -> (u8, u128, u64) {
        let band = match self.priority {
            Priority::High => 0u8,
            Priority::Normal => 1,
            Priority::Low => 2,
        };
        let dl = match self.deadline {
            Some(d) => d,
            // No deadline sorts after every real one: a year out.
            None => self.job.enqueued + Duration::from_secs(86_400 * 365),
        };
        (band, instant_key(dl), self.seq)
    }
}

/// Monotone ordering key for an `Instant` (nanos since process start-ish
/// epoch; only comparisons matter).
fn instant_key(t: Instant) -> u128 {
    use std::sync::OnceLock;
    static EPOCH: OnceLock<Instant> = OnceLock::new();
    let e = *EPOCH.get_or_init(Instant::now);
    t.saturating_duration_since(e).as_nanos()
}

struct State {
    groups: BTreeMap<GroupKey, Vec<QueuedJob>>,
    depth: usize,
    seq: u64,
    /// Last replica that served each group (routing preference).
    affinity: BTreeMap<GroupKey, usize>,
    shutdown: bool,
}

impl State {
    fn insert(&mut self, key: GroupKey, qj: QueuedJob, policy: SchedPolicy) {
        let g = self.groups.entry(key).or_default();
        match policy {
            SchedPolicy::Fifo => g.push(qj),
            SchedPolicy::Edf => {
                let k = qj.edf_key();
                let pos = g.partition_point(|x| x.edf_key() <= k);
                g.insert(pos, qj);
            }
        }
        self.depth += 1;
    }
}

/// Affinity entries kept once the map outgrows this bound: the key
/// space is partly client-controlled (γ and σ-bits come off the wire),
/// so the last-server map must not grow without limit on a long-running
/// server — dead groups' entries are pruned past this size.
const MAX_AFFINITY: usize = 256;

/// The bounded admission queue shared by HTTP workers and the replica
/// pool. See the module docs for the invariants.
pub struct AdmissionQueue {
    state: Mutex<State>,
    cond: Condvar,
    cap: usize,
    policy: SchedPolicy,
    retry_after_ms: u64,
    metrics: Arc<Metrics>,
    /// Flight recorder (None = tracing disabled, zero cost). The queue
    /// records the lifecycle events it owns: admission, queue-wait spans
    /// at dispatch, sheds, deadline expiries, requeues, and steals.
    trace: Option<Arc<TraceSink>>,
    /// External drain signal: when set, `next_batch` returns `None` at
    /// the next wakeup even without `shutdown()` (the pre-scheduler
    /// engine loop honored its stop flag the same way).
    stop: Arc<AtomicBool>,
    /// Graceful-drain latch: set by [`AdmissionQueue::begin_drain`].
    /// While draining, `admit` refuses with [`ServeError::Draining`] but
    /// `next_batch` keeps dispatching until the backlog is empty.
    draining: AtomicBool,
    /// Model-swap interrupt epoch. Bumping it wakes every replica parked
    /// in [`AdmissionQueue::next_batch_or_interrupt`] so they can rebind
    /// to new weights *between* batches — queued jobs are untouched, so
    /// a swap never drops a request.
    epoch: AtomicU64,
}

/// What a replica gets back from
/// [`AdmissionQueue::next_batch_or_interrupt`].
pub enum NextBatch {
    /// A decode batch from one compatibility group, in dispatch order.
    Batch(GroupKey, Vec<QueuedJob>),
    /// The queue's epoch moved past the replica's observed value (a
    /// model swap is in flight). No jobs were removed — re-observe the
    /// epoch, rebind, and call again.
    Interrupted,
    /// Stop flag or shutdown: the replica should exit its serve loop.
    Shutdown,
}

impl AdmissionQueue {
    /// Queue bounded at `cap` jobs, dispatching per `policy`, shedding
    /// with a `retry_after_ms` back-off hint, counting into `metrics`.
    /// Replicas drain out when `stop` is set or `shutdown()` is called.
    pub fn new(
        cap: usize,
        policy: SchedPolicy,
        retry_after_ms: u64,
        metrics: Arc<Metrics>,
        trace: Option<Arc<TraceSink>>,
        stop: Arc<AtomicBool>,
    ) -> AdmissionQueue {
        AdmissionQueue {
            state: Mutex::new(State {
                groups: BTreeMap::new(),
                depth: 0,
                seq: 0,
                affinity: BTreeMap::new(),
                shutdown: false,
            }),
            cond: Condvar::new(),
            cap,
            policy,
            retry_after_ms,
            metrics,
            trace,
            stop,
            draining: AtomicBool::new(false),
            epoch: AtomicU64::new(0),
        }
    }

    /// The current swap-interrupt epoch. Replicas snapshot this before
    /// blocking in [`AdmissionQueue::next_batch_or_interrupt`].
    pub fn epoch(&self) -> u64 {
        self.epoch.load(AtomicOrdering::SeqCst)
    }

    /// Advance the swap-interrupt epoch and wake every parked replica.
    /// Queued jobs are untouched — replicas see
    /// [`NextBatch::Interrupted`], rebind their stacks, and resume
    /// draining the same backlog. Returns the new epoch.
    pub fn bump_epoch(&self) -> u64 {
        let e = self.epoch.fetch_add(1, AtomicOrdering::SeqCst) + 1;
        self.cond.notify_all();
        e
    }

    /// The dispatch policy this queue runs.
    pub fn policy(&self) -> SchedPolicy {
        self.policy
    }

    /// The hard depth cap.
    pub fn cap(&self) -> usize {
        self.cap
    }

    /// Jobs currently admitted and waiting.
    pub fn depth(&self) -> usize {
        self.state.lock().unwrap().depth
    }

    /// True when the queue is at its cap — the `/healthz` readiness
    /// signal (a saturated replica should be drained by the balancer).
    pub fn saturated(&self) -> bool {
        let s = self.state.lock().unwrap();
        s.depth >= self.cap
    }

    fn shed(&self, qj: QueuedJob) {
        self.metrics.sheds_total.fetch_add(1, AtomicOrdering::Relaxed);
        // A shed request with a deadline is a missed SLO: the client
        // asked for a bound and got a 429 instead of a forecast.
        if qj.deadline_ms.is_some() {
            self.metrics.record_deadline_outcome(qj.priority.as_str(), false);
        }
        if let Some(t) = &self.trace {
            t.record(
                qj.job.req.request_id.unwrap_or(0),
                EventKind::Shed { priority: qj.priority as u8 },
            );
        }
        let _ = qj.job.reply.send(Err(ServeError::Shed { retry_after_ms: self.retry_after_ms }));
    }

    fn expire(&self, qj: QueuedJob, now: Instant) {
        self.metrics.expired_total.fetch_add(1, AtomicOrdering::Relaxed);
        // An expired deadline is by definition a missed SLO — without
        // this, attainment gauges would be computed only over requests
        // that decoded, overstating exactly under overload.
        self.metrics.record_deadline_outcome(qj.priority.as_str(), false);
        let waited_ms = now.saturating_duration_since(qj.job.enqueued).as_millis() as u64;
        if let Some(t) = &self.trace {
            t.record(
                qj.job.req.request_id.unwrap_or(0),
                EventKind::Expired { deadline_ms: qj.deadline_ms.unwrap_or(0), waited_ms },
            );
        }
        let _ = qj.job.reply.send(Err(ServeError::DeadlineExpired {
            deadline_ms: qj.deadline_ms.unwrap_or(0),
            waited_ms,
        }));
    }

    /// Drop every queued job whose deadline has passed, answering each
    /// with [`ServeError::DeadlineExpired`]. Expired jobs never decode.
    fn purge_expired(&self, s: &mut State) {
        let now = Instant::now();
        let mut expired: Vec<QueuedJob> = Vec::new();
        for g in s.groups.values_mut() {
            let mut i = 0;
            while i < g.len() {
                if g[i].deadline.map(|d| d <= now).unwrap_or(false) {
                    expired.push(g.remove(i));
                } else {
                    i += 1;
                }
            }
        }
        s.depth -= expired.len();
        for qj in expired {
            self.expire(qj, now);
        }
        s.groups.retain(|_, g| !g.is_empty());
        self.metrics.set_gauge("queue_depth", s.depth as f64);
    }

    /// Admit one job into `key`'s group. At the cap: under FIFO the
    /// arrival is shed; under EDF the worst queued job is evicted if the
    /// arrival outranks it (strictly higher priority), else the arrival
    /// is shed. Returns the shed error so the HTTP layer can answer
    /// without waiting on the reply channel.
    pub fn admit(
        &self,
        job: Job,
        priority: Priority,
        deadline_ms: Option<u64>,
        key: GroupKey,
    ) -> Result<(), ServeError> {
        if self.draining.load(AtomicOrdering::Relaxed) {
            return Err(ServeError::Draining);
        }
        let mut s = self.state.lock().unwrap();
        if s.shutdown {
            return Err(ServeError::Internal("server is shutting down".into()));
        }
        self.purge_expired(&mut s);
        if s.depth >= self.cap {
            let evicted = match self.policy {
                SchedPolicy::Fifo => None,
                SchedPolicy::Edf => self.evict_worse_than(&mut s, priority),
            };
            match evicted {
                Some(victim) => self.shed(victim),
                None => {
                    drop(s);
                    self.metrics.sheds_total.fetch_add(1, AtomicOrdering::Relaxed);
                    if deadline_ms.is_some() {
                        self.metrics.record_deadline_outcome(priority.as_str(), false);
                    }
                    if let Some(t) = &self.trace {
                        t.record(
                            job.req.request_id.unwrap_or(0),
                            EventKind::Shed { priority: priority as u8 },
                        );
                    }
                    return Err(ServeError::Shed { retry_after_ms: self.retry_after_ms });
                }
            }
        }
        let seq = s.seq;
        s.seq += 1;
        if let Some(t) = &self.trace {
            t.record(
                job.req.request_id.unwrap_or(0),
                EventKind::Admitted {
                    priority: priority as u8,
                    deadline_ms: deadline_ms.unwrap_or(0),
                },
            );
        }
        let deadline = deadline_ms.map(|ms| job.enqueued + Duration::from_millis(ms));
        s.insert(
            key,
            QueuedJob { job, priority, deadline, deadline_ms, seq, requeued: false },
            self.policy,
        );
        self.metrics.set_gauge("queue_depth", s.depth as f64);
        self.cond.notify_all();
        Ok(())
    }

    /// Put an already-admitted job back after its replica failed mid
    /// batch. Cap-exempt (the job held a queue slot moments ago; shedding
    /// it now would turn one replica crash into spurious 429s) but
    /// **once-only**: the caller must check [`QueuedJob::requeued`] and
    /// answer with [`ServeError::ReplicaFailure`] instead of calling this
    /// again. After `shutdown()` the job is failed rather than parked on
    /// a queue nobody will drain.
    pub fn requeue(&self, key: GroupKey, mut qj: QueuedJob) {
        debug_assert!(!qj.requeued, "requeue-once violated");
        qj.requeued = true;
        {
            let mut s = self.state.lock().unwrap();
            if s.shutdown {
                drop(s);
                let _ = qj.job.reply.send(Err(ServeError::Internal("server shut down".into())));
                return;
            }
            // Keep the original seq: the job re-enters at its old spot in
            // arrival order rather than the back of the line.
            if let Some(t) = &self.trace {
                t.record(qj.job.req.request_id.unwrap_or(0), EventKind::Requeued);
            }
            s.insert(key, qj, self.policy);
            self.metrics.set_gauge("queue_depth", s.depth as f64);
        }
        self.metrics.inc("requeues", 1);
        self.cond.notify_all();
    }

    /// Enter graceful drain: refuse new admissions with
    /// [`ServeError::Draining`] while replicas keep working the backlog.
    /// Idempotent. The server layer polls [`AdmissionQueue::depth`]
    /// against its drain deadline, then calls `shutdown()`.
    pub fn begin_drain(&self) {
        self.draining.store(true, AtomicOrdering::Relaxed);
        self.cond.notify_all();
    }

    /// True once `begin_drain` has been called.
    pub fn is_draining(&self) -> bool {
        self.draining.load(AtomicOrdering::Relaxed)
    }

    /// Remove and return the worst queued job (lowest band, then latest
    /// deadline, then newest) *iff* it ranks strictly below `incoming`.
    fn evict_worse_than(&self, s: &mut State, incoming: Priority) -> Option<QueuedJob> {
        let mut worst: Option<(GroupKey, usize)> = None;
        let mut worst_key = (0u8, 0u128, 0u64);
        for (k, g) in &s.groups {
            for (i, qj) in g.iter().enumerate() {
                if qj.priority >= incoming {
                    continue;
                }
                // Reuse the EDF key; "worst" = largest.
                let key = qj.edf_key();
                if worst.is_none() || key > worst_key {
                    worst = Some((*k, i));
                    worst_key = key;
                }
            }
        }
        let (gk, i) = worst?;
        let victim = s.groups.get_mut(&gk).unwrap().remove(i);
        if s.groups.get(&gk).unwrap().is_empty() {
            s.groups.remove(&gk);
        }
        s.depth -= 1;
        Some(victim)
    }

    /// Pick this replica's next decode batch: up to `max_batch` jobs
    /// from one group, in dispatch order. Blocks until work is
    /// available, the group has either filled to `max_batch` or aged
    /// past `max_wait` (the dynamic-batching window), or the queue shuts
    /// down (`None`).
    ///
    /// Group choice: the most urgent head among groups this replica has
    /// affinity for (or that nobody owns); when it has none, it *steals*
    /// the most urgent foreign group — an idle replica never sits behind
    /// another replica's backlog. Affinity follows the pop.
    pub fn next_batch(
        &self,
        replica: usize,
        max_batch: usize,
        max_wait: Duration,
    ) -> Option<(GroupKey, Vec<QueuedJob>)> {
        loop {
            match self.next_batch_or_interrupt(replica, max_batch, max_wait, self.epoch()) {
                NextBatch::Batch(key, batch) => return Some((key, batch)),
                NextBatch::Shutdown => return None,
                // Callers of the legacy entry point don't rebind on swap;
                // re-observe the epoch and keep waiting.
                NextBatch::Interrupted => continue,
            }
        }
    }

    /// [`AdmissionQueue::next_batch`] with a swap-interrupt contract:
    /// returns [`NextBatch::Interrupted`] (removing no jobs) as soon as
    /// the queue's epoch differs from `observed_epoch`, so a replica
    /// parked in its batching window reacts to a live weight swap
    /// immediately instead of after the window expires. The replica pool
    /// is the intended caller; [`AdmissionQueue::next_batch`] keeps the
    /// pre-swap contract for everything else.
    pub fn next_batch_or_interrupt(
        &self,
        replica: usize,
        max_batch: usize,
        max_wait: Duration,
        observed_epoch: u64,
    ) -> NextBatch {
        let mut s = self.state.lock().unwrap();
        loop {
            if self.stop.load(AtomicOrdering::Relaxed) {
                return NextBatch::Shutdown;
            }
            if self.epoch() != observed_epoch {
                return NextBatch::Interrupted;
            }
            self.purge_expired(&mut s);
            if let Some((key, stolen)) = self.choose_group(&s, replica) {
                let g = s.groups.get(&key).unwrap();
                let oldest = g.iter().map(|qj| qj.job.enqueued).min().unwrap();
                let aged = oldest.elapsed() >= max_wait;
                if g.len() >= max_batch || aged || s.depth >= self.cap {
                    let g = s.groups.get_mut(&key).unwrap();
                    let n = g.len().min(max_batch);
                    let batch: Vec<QueuedJob> = g.drain(..n).collect();
                    if g.is_empty() {
                        s.groups.remove(&key);
                    }
                    s.depth -= batch.len();
                    if stolen {
                        self.metrics.inc("steals", 1);
                        if let Some(t) = &self.trace {
                            t.record(0, EventKind::Steal { replica: replica as u32 });
                        }
                    }
                    let now = Instant::now();
                    for qj in &batch {
                        let waited = now.saturating_duration_since(qj.job.enqueued);
                        self.metrics.observe("queue_wait", waited);
                        if let Some(t) = &self.trace {
                            t.record_span_ending_now(
                                qj.job.req.request_id.unwrap_or(0),
                                waited,
                                EventKind::Dispatched { replica: replica as u32 },
                            );
                        }
                    }
                    s.affinity.insert(key, replica);
                    // γ and σ-bits in the key come off the wire, so the
                    // affinity map is client-growable: prune entries of
                    // dead groups past a fixed bound.
                    if s.affinity.len() > MAX_AFFINITY {
                        let State { groups, affinity, .. } = &mut *s;
                        affinity.retain(|k, _| *k == key || groups.contains_key(k));
                    }
                    self.metrics.set_gauge("queue_depth", s.depth as f64);
                    // Waking peers matters: more groups may remain.
                    self.cond.notify_all();
                    return NextBatch::Batch(key, batch);
                }
                // Wait out the batching window for this group to fill.
                let remaining = max_wait.saturating_sub(oldest.elapsed());
                let (ns, _) = self.cond.wait_timeout(s, remaining).unwrap();
                s = ns;
            } else if s.shutdown {
                return NextBatch::Shutdown;
            } else {
                let (ns, _) = self.cond.wait_timeout(s, Duration::from_millis(50)).unwrap();
                s = ns;
            }
        }
    }

    /// The most urgent non-empty group this replica should serve, and
    /// whether taking it is a steal (it was last served by someone
    /// else). Preference order: own/unowned groups, then foreign ones.
    fn choose_group(&self, s: &State, replica: usize) -> Option<(GroupKey, bool)> {
        let head_key = |g: &Vec<QueuedJob>| match self.policy {
            SchedPolicy::Edf => g[0].edf_key(),
            SchedPolicy::Fifo => (0, 0, g[0].seq),
        };
        let mut best_mine: Option<(GroupKey, (u8, u128, u64))> = None;
        let mut best_foreign: Option<(GroupKey, (u8, u128, u64))> = None;
        for (k, g) in &s.groups {
            if g.is_empty() {
                continue;
            }
            let hk = head_key(g);
            let owner = s.affinity.get(k).copied();
            let slot = if owner.is_none() || owner == Some(replica) {
                &mut best_mine
            } else {
                &mut best_foreign
            };
            if slot.as_ref().map(|(_, bk)| hk < *bk).unwrap_or(true) {
                *slot = Some((*k, hk));
            }
        }
        match (best_mine, best_foreign) {
            (Some((k, _)), _) => Some((k, false)),
            (None, Some((k, _))) => Some((k, true)),
            (None, None) => None,
        }
    }

    /// Stop the queue: reject future admissions, wake all replicas (they
    /// exit on the next `next_batch`), and fail every still-queued job
    /// with an internal error.
    pub fn shutdown(&self) {
        let drained: Vec<QueuedJob> = {
            let mut s = self.state.lock().unwrap();
            s.shutdown = true;
            let mut all = Vec::new();
            for (_, mut g) in std::mem::take(&mut s.groups) {
                all.append(&mut g);
            }
            s.depth = 0;
            all
        };
        for qj in drained {
            let _ = qj.job.reply.send(Err(ServeError::Internal("server shut down".into())));
        }
        self.cond.notify_all();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::server::protocol::{ForecastRequest, ForecastResponse, Mode};
    use std::sync::mpsc;

    fn req() -> ForecastRequest {
        ForecastRequest {
            history: vec![0.0; 4],
            horizon: 1,
            mode: Mode::Sd,
            gamma: None,
            k: None,
            sigma: None,
            cache: None,
            adaptive: None,
            draft: None,
            dataset: None,
            priority: Priority::Normal,
            deadline_ms: None,
            seed: None,
            request_id: None,
        }
    }

    fn mk_job() -> (Job, mpsc::Receiver<Result<ForecastResponse, ServeError>>) {
        let (tx, rx) = mpsc::sync_channel(1);
        (Job { req: req(), enqueued: Instant::now(), reply: tx }, rx)
    }

    fn key(gamma: usize) -> GroupKey {
        GroupKey::Sd {
            gamma,
            k: 1,
            sigma_bits: 0.5f64.to_bits(),
            cache: true,
            adaptive: false,
            kind: DraftKind::Model,
        }
    }

    #[test]
    fn tree_k_is_a_grouping_axis() {
        // Same γ/σ/cache/kind but different k must land in different
        // decode groups: k = 1 runs the lockstep batched engine, k > 1
        // runs per-job tree decodes.
        let k1 = key(3);
        let k4 = match key(3) {
            GroupKey::Sd { gamma, sigma_bits, cache, adaptive, kind, .. } => {
                GroupKey::Sd { gamma, k: 4, sigma_bits, cache, adaptive, kind }
            }
            other => other,
        };
        assert_ne!(k1, k4);
        let q = queue(16, SchedPolicy::Edf);
        for gk in [k1, k4] {
            let (job, _rx) = mk_job();
            std::mem::forget(_rx);
            q.admit(job, Priority::Normal, None, gk).unwrap();
        }
        let (ka, _) = q.next_batch(0, 16, Duration::ZERO).unwrap();
        let (kb, _) = q.next_batch(0, 16, Duration::ZERO).unwrap();
        assert_ne!(ka, kb, "k = 1 and k = 4 jobs must not share a batch");
    }

    fn queue(cap: usize, policy: SchedPolicy) -> AdmissionQueue {
        AdmissionQueue::new(
            cap,
            policy,
            750,
            Arc::new(Metrics::new()),
            None,
            Arc::new(AtomicBool::new(false)),
        )
    }

    #[test]
    fn admits_and_dispatches_in_priority_then_deadline_order() {
        let q = queue(16, SchedPolicy::Edf);
        let mut rxs = Vec::new();
        // Mixed arrivals: (priority, deadline_ms).
        let arrivals = [
            (Priority::Low, None),
            (Priority::High, Some(500u64)),
            (Priority::Normal, Some(100)),
            (Priority::High, Some(100)),
            (Priority::Normal, None),
        ];
        for (p, d) in arrivals {
            let (job, rx) = mk_job();
            q.admit(job, p, d, key(3)).unwrap();
            rxs.push(rx);
        }
        assert_eq!(q.depth(), 5);
        let (_, batch) = q.next_batch(0, 16, Duration::ZERO).unwrap();
        let order: Vec<(Priority, Option<u64>)> =
            batch.iter().map(|qj| (qj.priority, qj.deadline_ms)).collect();
        assert_eq!(
            order,
            vec![
                (Priority::High, Some(100)),
                (Priority::High, Some(500)),
                (Priority::Normal, Some(100)),
                (Priority::Normal, None),
                (Priority::Low, None),
            ]
        );
        assert_eq!(q.depth(), 0);
    }

    #[test]
    fn fifo_policy_preserves_arrival_order() {
        let q = queue(16, SchedPolicy::Fifo);
        for (p, d) in [(Priority::Low, None), (Priority::High, Some(50u64)), (Priority::Normal, None)]
        {
            let (job, _rx) = mk_job();
            std::mem::forget(_rx);
            q.admit(job, p, d, key(3)).unwrap();
        }
        let (_, batch) = q.next_batch(0, 16, Duration::ZERO).unwrap();
        let order: Vec<Priority> = batch.iter().map(|qj| qj.priority).collect();
        assert_eq!(order, vec![Priority::Low, Priority::High, Priority::Normal]);
    }

    #[test]
    fn saturation_sheds_and_high_priority_evicts_low() {
        let m = Arc::new(Metrics::new());
        let q =
            AdmissionQueue::new(2, SchedPolicy::Edf, 750, m.clone(), None, Arc::new(AtomicBool::new(false)));
        let (j1, rx1) = mk_job();
        q.admit(j1, Priority::Low, None, key(3)).unwrap();
        let (j2, _rx2) = mk_job();
        q.admit(j2, Priority::Normal, None, key(3)).unwrap();
        assert!(q.saturated());
        // A low arrival at the cap is shed outright (nothing outranked).
        let (j3, _rx3) = mk_job();
        let err = q.admit(j3, Priority::Low, None, key(3)).unwrap_err();
        assert!(matches!(err, ServeError::Shed { retry_after_ms: 750 }));
        // A high arrival evicts the queued low.
        let (j4, _rx4) = mk_job();
        q.admit(j4, Priority::High, None, key(3)).unwrap();
        let evicted = rx1.try_recv().unwrap().unwrap_err();
        assert_eq!(evicted.code(), "shed");
        assert_eq!(q.depth(), 2);
        assert_eq!(m.sheds_total.load(AtomicOrdering::Relaxed), 2);
        // The surviving batch holds high + normal.
        let (_, batch) = q.next_batch(0, 16, Duration::ZERO).unwrap();
        let bands: Vec<Priority> = batch.iter().map(|qj| qj.priority).collect();
        assert_eq!(bands, vec![Priority::High, Priority::Normal]);
    }

    #[test]
    fn fifo_saturation_tail_drops_regardless_of_priority() {
        let q = queue(1, SchedPolicy::Fifo);
        let (j1, _rx1) = mk_job();
        q.admit(j1, Priority::Low, None, key(3)).unwrap();
        let (j2, _rx2) = mk_job();
        let err = q.admit(j2, Priority::High, None, key(3)).unwrap_err();
        assert_eq!(err.code(), "shed");
    }

    #[test]
    fn expired_jobs_are_purged_and_never_dispatched() {
        let m = Arc::new(Metrics::new());
        let q = AdmissionQueue::new(
            16,
            SchedPolicy::Edf,
            750,
            m.clone(),
            None,
            Arc::new(AtomicBool::new(false)),
        );
        let (j1, rx1) = mk_job();
        q.admit(j1, Priority::Normal, Some(1), key(3)).unwrap();
        let (j2, _rx2) = mk_job();
        q.admit(j2, Priority::Normal, Some(60_000), key(3)).unwrap();
        std::thread::sleep(Duration::from_millis(10));
        let (_, batch) = q.next_batch(0, 16, Duration::ZERO).unwrap();
        assert_eq!(batch.len(), 1, "expired job must not dispatch");
        assert_eq!(batch[0].deadline_ms, Some(60_000));
        let e = rx1.try_recv().unwrap().unwrap_err();
        assert_eq!(e.code(), "deadline_expired");
        assert_eq!(e.http_status(), 504);
        assert_eq!(m.expired_total.load(AtomicOrdering::Relaxed), 1);
        // An expired deadline is a missed SLO: the attainment gauge must
        // see it even though the request never decoded.
        assert_eq!(m.counter("deadline_missed_normal"), 1);
        assert_eq!(m.gauge("slo_attainment_normal"), Some(0.0));
    }

    #[test]
    fn stop_flag_unblocks_idle_replicas() {
        let stop = Arc::new(AtomicBool::new(false));
        let q = Arc::new(AdmissionQueue::new(
            16,
            SchedPolicy::Edf,
            750,
            Arc::new(Metrics::new()),
            None,
            stop.clone(),
        ));
        let q2 = q.clone();
        let waiter = std::thread::spawn(move || q2.next_batch(0, 8, Duration::from_millis(5)));
        std::thread::sleep(Duration::from_millis(20));
        // No shutdown() call — the stop flag alone must drain the
        // replica out of its idle wait (the pre-scheduler contract).
        stop.store(true, AtomicOrdering::Relaxed);
        let out = waiter.join().unwrap();
        assert!(out.is_none(), "stopped replica must exit without work");
    }

    #[test]
    fn groups_do_not_mix_and_stealing_is_counted() {
        let m = Arc::new(Metrics::new());
        let q = AdmissionQueue::new(
            16,
            SchedPolicy::Edf,
            750,
            m.clone(),
            None,
            Arc::new(AtomicBool::new(false)),
        );
        for g in [2usize, 3] {
            for _ in 0..2 {
                let (job, _rx) = mk_job();
                std::mem::forget(_rx);
                q.admit(job, Priority::Normal, None, key(g)).unwrap();
            }
        }
        // Replica 0 serves one group; affinity sticks.
        let (k0, b0) = q.next_batch(0, 16, Duration::ZERO).unwrap();
        assert_eq!(b0.len(), 2);
        // Replica 1 takes the other group — unowned, not a steal.
        let (k1, b1) = q.next_batch(1, 16, Duration::ZERO).unwrap();
        assert_eq!(b1.len(), 2);
        assert_ne!(k0, k1);
        assert_eq!(m.counter("steals"), 0);
        // More work lands in replica 0's group, but replica 1 grabs it:
        // that is a steal.
        let (job, _rx) = mk_job();
        std::mem::forget(_rx);
        q.admit(job, Priority::Normal, None, k0).unwrap();
        let (k, _) = q.next_batch(1, 16, Duration::ZERO).unwrap();
        assert_eq!(k, k0);
        assert_eq!(m.counter("steals"), 1);
    }

    #[test]
    fn batching_window_fills_before_dispatch() {
        let q = Arc::new(queue(16, SchedPolicy::Edf));
        let (job, _rx) = mk_job();
        std::mem::forget(_rx);
        q.admit(job, Priority::Normal, None, key(3)).unwrap();
        // A second job lands while the replica is inside its batching
        // window; both must come out in one batch.
        let q2 = q.clone();
        let t = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(20));
            let (job, _rx) = mk_job();
            std::mem::forget(_rx);
            q2.admit(job, Priority::Normal, None, key(3)).unwrap();
        });
        let (_, batch) = q.next_batch(0, 8, Duration::from_millis(200)).unwrap();
        t.join().unwrap();
        assert_eq!(batch.len(), 2, "window should have batched both jobs");
    }

    #[test]
    fn requeue_is_cap_exempt_and_marks_the_job() {
        let m = Arc::new(Metrics::new());
        let q =
            AdmissionQueue::new(1, SchedPolicy::Edf, 750, m.clone(), None, Arc::new(AtomicBool::new(false)));
        let (j1, _rx1) = mk_job();
        q.admit(j1, Priority::Normal, None, key(3)).unwrap();
        let (_, mut batch) = q.next_batch(0, 8, Duration::ZERO).unwrap();
        let taken = batch.pop().unwrap();
        assert!(!taken.requeued);
        // Fill the queue back to its cap, then requeue the taken job:
        // it must re-enter even though depth == cap.
        let (j2, _rx2) = mk_job();
        q.admit(j2, Priority::Normal, None, key(3)).unwrap();
        assert!(q.saturated());
        let orig_seq = taken.seq;
        q.requeue(key(3), taken);
        assert_eq!(q.depth(), 2);
        assert_eq!(m.counter("requeues"), 1);
        let (_, batch) = q.next_batch(0, 8, Duration::ZERO).unwrap();
        let back = batch.iter().find(|qj| qj.seq == orig_seq).unwrap();
        assert!(back.requeued, "requeued job must carry the once-only marker");
        // The requeued job kept its arrival position (EDF tiebreak by
        // seq), so it dispatches ahead of the younger admission.
        assert_eq!(batch[0].seq, orig_seq);
    }

    #[test]
    fn requeue_after_shutdown_fails_the_job() {
        let q = queue(4, SchedPolicy::Edf);
        let (j1, rx1) = mk_job();
        q.admit(j1, Priority::Normal, None, key(3)).unwrap();
        let (_, mut batch) = q.next_batch(0, 8, Duration::ZERO).unwrap();
        q.shutdown();
        q.requeue(key(3), batch.pop().unwrap());
        let e = rx1.recv_timeout(Duration::from_secs(1)).unwrap().unwrap_err();
        assert_eq!(e.code(), "internal");
    }

    #[test]
    fn drain_refuses_admissions_but_keeps_dispatching() {
        let q = queue(16, SchedPolicy::Edf);
        let (j1, _rx1) = mk_job();
        q.admit(j1, Priority::Normal, None, key(3)).unwrap();
        assert!(!q.is_draining());
        q.begin_drain();
        assert!(q.is_draining());
        // New work is refused with the typed draining error...
        let (j2, _rx2) = mk_job();
        let err = q.admit(j2, Priority::Normal, None, key(3)).unwrap_err();
        assert_eq!(err.code(), "draining");
        assert_eq!(err.http_status(), 503);
        // ...but the backlog still dispatches to replicas.
        let (_, batch) = q.next_batch(0, 8, Duration::ZERO).unwrap();
        assert_eq!(batch.len(), 1);
        assert_eq!(q.depth(), 0);
    }

    #[test]
    fn shutdown_fails_queued_jobs_and_unblocks_replicas() {
        let q = Arc::new(queue(16, SchedPolicy::Edf));
        let (job, rx) = mk_job();
        q.admit(job, Priority::Normal, None, key(3)).unwrap();
        let q2 = q.clone();
        let waiter = std::thread::spawn(move || q2.next_batch(0, 8, Duration::from_secs(60)));
        // Give the waiter time to enter its batching window, then pull
        // the plug.
        std::thread::sleep(Duration::from_millis(30));
        q.shutdown();
        // The queued job is answered, replicas drain out, and future
        // admissions are refused.
        match waiter.join().unwrap() {
            None => {
                let e = rx.recv_timeout(Duration::from_secs(1)).unwrap().unwrap_err();
                assert_eq!(e.code(), "internal");
            }
            Some((_, batch)) => {
                // The waiter may legitimately win the race and take the
                // job before shutdown drains it.
                assert_eq!(batch.len(), 1);
            }
        }
        let (job, _rx) = mk_job();
        assert!(q.admit(job, Priority::Normal, None, key(3)).is_err());
    }

    #[test]
    fn epoch_bump_interrupts_an_idle_replica_without_touching_jobs() {
        let q = Arc::new(queue(16, SchedPolicy::Edf));
        // A job sits mid batching-window so the replica is parked inside
        // the group-fill wait, not the idle wait.
        let (job, _rx) = mk_job();
        std::mem::forget(_rx);
        q.admit(job, Priority::Normal, None, key(3)).unwrap();
        let q2 = q.clone();
        let observed = q.epoch();
        let waiter = std::thread::spawn(move || {
            q2.next_batch_or_interrupt(0, 8, Duration::from_secs(60), observed)
        });
        std::thread::sleep(Duration::from_millis(30));
        let new_epoch = q.bump_epoch();
        assert_eq!(new_epoch, observed + 1);
        match waiter.join().unwrap() {
            NextBatch::Interrupted => {}
            NextBatch::Batch(..) => panic!("swap interrupt must win over the batching window"),
            NextBatch::Shutdown => panic!("epoch bump is not a shutdown"),
        }
        // The interrupt removed nothing: the job is still queued and the
        // replica picks it up on the next call at the new epoch.
        assert_eq!(q.depth(), 1);
        match q.next_batch_or_interrupt(0, 8, Duration::ZERO, new_epoch) {
            NextBatch::Batch(_, batch) => assert_eq!(batch.len(), 1),
            _ => panic!("job must survive the swap interrupt"),
        }
    }

    #[test]
    fn stale_epoch_interrupts_before_dispatch() {
        // A replica calling in with an out-of-date epoch must be told to
        // rebind even though work is immediately available — otherwise a
        // busy replica could keep serving old weights past the swap
        // barrier.
        let q = queue(16, SchedPolicy::Edf);
        let (job, _rx) = mk_job();
        std::mem::forget(_rx);
        q.admit(job, Priority::Normal, None, key(3)).unwrap();
        let stale = q.epoch();
        q.bump_epoch();
        assert!(matches!(
            q.next_batch_or_interrupt(0, 8, Duration::ZERO, stale),
            NextBatch::Interrupted
        ));
        // Legacy entry point is swap-oblivious: it re-observes and
        // dispatches as before.
        let (_, batch) = q.next_batch(0, 8, Duration::ZERO).unwrap();
        assert_eq!(batch.len(), 1);
    }
}
