//! The engine replica pool: N independent model/session stacks, each
//! running its own drain loop over the shared [`AdmissionQueue`].
//!
//! Replicas are built by a [`ReplicaBuilder`] *on the replica's own
//! thread* (the PJRT client is not `Send`, so the xla backend must be
//! constructed where it runs; the native builder hands every replica a
//! [`crate::models::NativeBackend::replicate`] stack over one shared
//! `Arc`-packed weight store — N replicas, one copy of the floats).
//!
//! Cross-replica state is deliberately small and mutex-guarded:
//! * the server's long-lived adaptive-γ controller (every finished
//!   group's rounds feed it, whichever replica ran them);
//! * the per-kind learned draft heads — a replica imports the current
//!   snapshot before a decode group and merges its export back
//!   (elementwise mean with the stored head), so online adaptation is
//!   pooled across the fleet instead of fragmenting per replica.
//!
//! Each decode group is **supervised**: it runs under `catch_unwind`,
//! so a panic inside a model forward (a bug, or an injected chaos
//! fault) costs one group, not the replica thread. The supervisor
//! answers every unreplied job through the [`GroupRun`] holder (typed
//! failure for the poisoned job, requeue-once for its group-mates),
//! rebuilds the replica's stacks through the same [`ReplicaBuilder`]
//! (on the native backend that re-clones `Arc` weight handles — no
//! floats reload), and keeps draining. When a
//! [`crate::faultinject::FaultPlan`] is armed, each replica's backends
//! are wrapped in [`FaultyBackend`] after the warm-up forward.

use std::collections::BTreeMap;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::time::Duration;

use anyhow::{Context, Result};

use crate::config::ServeConfig;
use crate::faultinject::{FaultPlan, FaultSite, FaultyBackend};
use crate::metrics::{AcceptanceMonitor, Metrics};
use crate::models::Backend;
use crate::specdec::{DraftKind, GammaController};

use super::super::batcher::{execute_batch, lock_ignore_poison, GroupRun};
use super::queue::AdmissionQueue;
use super::ModelShape;

/// One replica's owned backends (target + draft).
pub struct ReplicaStacks {
    /// The target (verifier) backend.
    pub target: Box<dyn Backend>,
    /// The draft (proposal) backend.
    pub draft: Box<dyn Backend>,
}

/// Constructs replica `i`'s stacks, called on that replica's thread.
/// Must be cheap on shared state (clone `Arc` weight handles, don't
/// reload blobs) and is the injection point that lets tests and benches
/// run the full serving stack over synthetic models.
pub type ReplicaBuilder = Arc<dyn Fn(usize) -> Result<ReplicaStacks> + Send + Sync>;

/// State shared by every replica (and read by the HTTP layer).
pub struct SchedShared {
    /// Serving metrics registry.
    pub metrics: Arc<Metrics>,
    /// Windowed acceptance monitor (paper §7 alerting).
    pub monitor: Arc<AcceptanceMonitor>,
    /// The server's long-lived adaptive-γ controller, when enabled.
    pub controller: Option<Arc<Mutex<GammaController>>>,
    /// Per-kind learned draft-head snapshots, merged across replicas.
    pub draft_heads: Mutex<BTreeMap<DraftKind, Vec<f32>>>,
    /// Seeded fault-injection schedule, when chaos is armed (`None` in
    /// normal operation — the hot path never consults it).
    pub fault_plan: Option<Arc<FaultPlan>>,
}

impl SchedShared {
    /// Current head snapshot for `kind`, if any replica exported one.
    pub fn head_for(&self, kind: DraftKind) -> Option<Vec<f32>> {
        lock_ignore_poison(&self.draft_heads).get(&kind).cloned()
    }

    /// Fold a replica's exported head into the shared snapshot:
    /// elementwise mean with the stored head (deterministic, keeps every
    /// replica's adaptation represented), or replace it on a shape
    /// change.
    pub fn merge_head(&self, kind: DraftKind, head: Vec<f32>) {
        let mut hs = lock_ignore_poison(&self.draft_heads);
        match hs.get_mut(&kind) {
            Some(prev) if prev.len() == head.len() => {
                for (p, h) in prev.iter_mut().zip(&head) {
                    *p = 0.5 * (*p + *h);
                }
            }
            _ => {
                hs.insert(kind, head);
            }
        }
    }

    /// Drop a stored head (a replica found it stale/mis-shaped).
    pub fn discard_head(&self, kind: DraftKind) {
        lock_ignore_poison(&self.draft_heads).remove(&kind);
    }
}

/// Spawn `cfg.replicas` engine threads; blocks until every replica's
/// backends are loaded and warmed (or fails, after tearing the pool
/// down). Each thread drains the queue until shutdown.
pub fn start_pool(
    cfg: Arc<ServeConfig>,
    shape: ModelShape,
    builder: ReplicaBuilder,
    queue: Arc<AdmissionQueue>,
    shared: Arc<SchedShared>,
    stop: Arc<AtomicBool>,
) -> Result<Vec<std::thread::JoinHandle<()>>> {
    // Size the kernel compute pool before the first forward (first
    // initialization wins process-wide, exactly as the single-engine
    // loop did).
    let pool_size = if cfg.threads > 0 {
        crate::util::threadpool::init_global_pool(cfg.threads)
    } else {
        crate::util::threadpool::global_pool().size()
    };
    log::info!("kernel compute pool: {pool_size} threads");

    let (ready_tx, ready_rx) = mpsc::sync_channel::<Result<String, String>>(cfg.replicas);
    let mut handles = Vec::new();
    for r in 0..cfg.replicas {
        let cfg = Arc::clone(&cfg);
        let builder = Arc::clone(&builder);
        let queue = Arc::clone(&queue);
        let shared = Arc::clone(&shared);
        let stop = Arc::clone(&stop);
        let ready = ready_tx.clone();
        let handle = std::thread::Builder::new()
            .name(format!("stride-replica-{r}"))
            .spawn(move || {
                let stacks = match builder(r) {
                    Ok(s) => s,
                    Err(e) => {
                        let _ = ready.send(Err(format!("replica {r}: {e:#}")));
                        return;
                    }
                };
                // Warm both stacks so the first request doesn't pay
                // first-touch cost.
                let warm = vec![0.0f32; shape.n_ctx * shape.patch];
                let _ = stacks.target.forward(&warm, shape.n_ctx);
                let _ = stacks.draft.forward(&warm, shape.n_ctx);
                let _ = ready.send(Ok(format!(
                    "replica {r}: target={} draft={}",
                    stacks.target.name(),
                    stacks.draft.name()
                )));
                replica_main(r, &cfg, shape, stacks, &builder, &queue, &shared, &stop);
            })
            .context("spawning replica thread")?;
        handles.push(handle);
    }
    drop(ready_tx);

    let mut failure: Option<String> = None;
    for _ in 0..cfg.replicas {
        match ready_rx.recv() {
            Ok(Ok(desc)) => log::info!("engine ready: {desc}"),
            Ok(Err(e)) => {
                failure = Some(e);
                break;
            }
            Err(_) => {
                failure = Some("replica thread died during startup".into());
                break;
            }
        }
    }
    if let Some(e) = failure {
        // Tear down whatever did come up before reporting the failure.
        queue.shutdown();
        stop.store(true, Ordering::Relaxed);
        for h in handles {
            let _ = h.join();
        }
        anyhow::bail!("engine startup failed: {e}");
    }
    Ok(handles)
}

/// Wrap a replica's stacks in the chaos decorator when a fault plan is
/// armed; a no-op (and no wrapper on the hot path) otherwise.
fn arm(stacks: ReplicaStacks, shared: &SchedShared) -> ReplicaStacks {
    let Some(plan) = &shared.fault_plan else { return stacks };
    ReplicaStacks {
        target: FaultyBackend::wrap(stacks.target, Arc::clone(plan), FaultSite::Target),
        draft: FaultyBackend::wrap(stacks.draft, Arc::clone(plan), FaultSite::Draft),
    }
}

/// Best-effort text of a panic payload (for logs and the typed
/// `replica_failure` reply).
fn panic_message(payload: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

#[allow(clippy::too_many_arguments)]
fn replica_main(
    replica: usize,
    cfg: &ServeConfig,
    shape: ModelShape,
    stacks: ReplicaStacks,
    builder: &ReplicaBuilder,
    queue: &AdmissionQueue,
    shared: &SchedShared,
    stop: &AtomicBool,
) {
    let max_wait = Duration::from_millis(cfg.max_wait_ms);
    // Arm chaos only after the warm-up forwards, so startup cannot be
    // killed by its own injection schedule.
    let mut stacks = arm(stacks, shared);
    loop {
        if stop.load(Ordering::Relaxed) {
            return;
        }
        let Some((key, jobs)) = queue.next_batch(replica, cfg.max_batch, max_wait) else {
            return; // queue shut down
        };
        shared.metrics.inc("batches", 1);
        shared.metrics.inc("batched_jobs", jobs.len() as u64);
        shared.metrics.inc(&format!("replica_{replica}_batches"), 1);
        let run = GroupRun::new(jobs);
        let outcome = catch_unwind(AssertUnwindSafe(|| {
            execute_batch(
                cfg,
                shape,
                stacks.target.as_ref(),
                stacks.draft.as_ref(),
                key,
                &run,
                shared,
                replica,
            );
        }));
        if let Err(payload) = outcome {
            let msg = panic_message(payload.as_ref());
            log::error!("replica {replica} panicked mid-group, restarting: {msg}");
            shared.metrics.inc("replica_restarts", 1);
            run.recover_after_panic(key, queue, shared, &msg);
            // Rebind to the shared weight store: on the native backend
            // `replicate()` clones `Arc` handles, so a restart costs
            // session state, never a weight reload.
            match builder(replica) {
                Ok(fresh) => stacks = arm(fresh, shared),
                Err(e) => log::error!(
                    "replica {replica} stack rebuild failed, keeping prior stacks: {e:#}"
                ),
            }
        }
    }
}
