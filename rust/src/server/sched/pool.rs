//! The engine replica pool: N independent model/session stacks, each
//! running its own drain loop over the shared [`AdmissionQueue`].
//!
//! Replicas are built by a [`ReplicaBuilder`] *on the replica's own
//! thread* (the PJRT client is not `Send`, so the xla backend must be
//! constructed where it runs; the native builder hands every replica a
//! [`crate::models::NativeBackend::replicate`] stack over one shared
//! `Arc`-packed weight store — N replicas, one copy of the floats).
//!
//! Cross-replica state is deliberately small and mutex-guarded:
//! * the server's long-lived adaptive-γ controller (every finished
//!   group's rounds feed it, whichever replica ran them);
//! * the per-kind learned draft heads — a replica imports the current
//!   snapshot before a decode group and merges its export back
//!   (elementwise mean with the stored head), so online adaptation is
//!   pooled across the fleet instead of fragmenting per replica.
//!
//! Each decode group is **supervised**: it runs under `catch_unwind`,
//! so a panic inside a model forward (a bug, or an injected chaos
//! fault) costs one group, not the replica thread. The supervisor
//! answers every unreplied job through the [`GroupRun`] holder (typed
//! failure for the poisoned job, requeue-once for its group-mates),
//! rebuilds the replica's stacks through the same [`ReplicaBuilder`]
//! (on the native backend that re-clones `Arc` weight handles — no
//! floats reload), and keeps draining. When a
//! [`crate::faultinject::FaultPlan`] is armed, each replica's backends
//! are wrapped in [`FaultyBackend`] after the warm-up forward.

use std::collections::BTreeMap;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::config::ServeConfig;
use crate::faultinject::{FaultPlan, FaultSite, FaultyBackend};
use crate::metrics::{AcceptanceMonitor, Metrics};
use crate::models::Backend;
use crate::specdec::{DraftKind, GammaController};

use super::super::batcher::{execute_batch, lock_ignore_poison, GroupRun};
use super::queue::{AdmissionQueue, NextBatch};
use super::ModelShape;

/// One replica's owned backends (target + draft).
pub struct ReplicaStacks {
    /// The target (verifier) backend.
    pub target: Box<dyn Backend>,
    /// The draft (proposal) backend.
    pub draft: Box<dyn Backend>,
}

/// Constructs replica `i`'s stacks, called on that replica's thread.
/// Must be cheap on shared state (clone `Arc` weight handles, don't
/// reload blobs) and is the injection point that lets tests and benches
/// run the full serving stack over synthetic models.
pub type ReplicaBuilder = Arc<dyn Fn(usize) -> Result<ReplicaStacks> + Send + Sync>;

struct SlotInner {
    builder: ReplicaBuilder,
    digest: String,
    label: String,
}

/// The pool's live model binding: the [`ReplicaBuilder`] every replica
/// constructs its stacks from, plus the identity of the weights behind
/// it (registry manifest digest + human label) and a generation counter.
///
/// Live weight swap is two writes and a barrier: [`ModelSlot::swap`]
/// installs a new builder and bumps the generation, the caller bumps the
/// queue's interrupt epoch to wake parked replicas, and each replica
/// rebinds *between* decode batches — in-flight groups finish on the old
/// weights, queued jobs are untouched, so no request is ever dropped by
/// a swap. [`ModelSlot::wait_generation`] is the barrier: it returns
/// once every replica has acknowledged the new generation (or the
/// timeout expires, e.g. a replica wedged by injected chaos).
pub struct ModelSlot {
    inner: Mutex<SlotInner>,
    /// Lock-free mirror of the current generation for the serve loop's
    /// per-iteration check (bumped under `inner`'s lock, so a
    /// `snapshot()` pair is always consistent).
    generation: AtomicU64,
    /// Per-replica highest acknowledged generation (the swap barrier).
    acks: Mutex<BTreeMap<usize, u64>>,
    ack_cond: Condvar,
}

impl ModelSlot {
    /// A slot serving `builder`, identified by `digest` (registry
    /// manifest content address, or `"unregistered"` for builders that
    /// did not come from the registry) and a display `label`.
    pub fn new(builder: ReplicaBuilder, digest: &str, label: &str) -> ModelSlot {
        ModelSlot {
            inner: Mutex::new(SlotInner {
                builder,
                digest: digest.to_string(),
                label: label.to_string(),
            }),
            generation: AtomicU64::new(0),
            acks: Mutex::new(BTreeMap::new()),
            ack_cond: Condvar::new(),
        }
    }

    /// The current swap generation (0 = the stacks the pool booted with).
    pub fn generation(&self) -> u64 {
        self.generation.load(Ordering::SeqCst)
    }

    /// The serving manifest digest (`/healthz` + `/stats` identity).
    pub fn digest(&self) -> String {
        lock_ignore_poison(&self.inner).digest.clone()
    }

    /// The serving model's display label (`name:version` reference).
    pub fn label(&self) -> String {
        lock_ignore_poison(&self.inner).label.clone()
    }

    /// A consistent (builder, generation) pair for one rebind.
    pub fn snapshot(&self) -> (ReplicaBuilder, u64) {
        let inner = lock_ignore_poison(&self.inner);
        (Arc::clone(&inner.builder), self.generation.load(Ordering::SeqCst))
    }

    /// Install a new builder + identity and advance the generation.
    /// Returns the new generation. The caller must follow with
    /// [`AdmissionQueue::bump_epoch`] so parked replicas notice.
    pub fn swap(&self, builder: ReplicaBuilder, digest: &str, label: &str) -> u64 {
        let mut inner = lock_ignore_poison(&self.inner);
        inner.builder = builder;
        inner.digest = digest.to_string();
        inner.label = label.to_string();
        self.generation.fetch_add(1, Ordering::SeqCst) + 1
    }

    /// Record that `replica` now serves `generation` (monotone).
    pub fn ack(&self, replica: usize, generation: u64) {
        let mut acks = lock_ignore_poison(&self.acks);
        let e = acks.entry(replica).or_insert(0);
        if generation > *e {
            *e = generation;
        }
        self.ack_cond.notify_all();
    }

    /// Replicas currently acknowledging a generation `>= generation`.
    pub fn replicas_at(&self, generation: u64) -> usize {
        lock_ignore_poison(&self.acks).values().filter(|g| **g >= generation).count()
    }

    /// Block until `replicas` replicas acknowledge `generation` (true)
    /// or `timeout` expires (false — the swap is still installed; any
    /// straggler rebinds before its next batch).
    pub fn wait_generation(&self, generation: u64, replicas: usize, timeout: Duration) -> bool {
        let deadline = Instant::now() + timeout;
        let mut acks = lock_ignore_poison(&self.acks);
        loop {
            if acks.values().filter(|g| **g >= generation).count() >= replicas {
                return true;
            }
            let now = Instant::now();
            if now >= deadline {
                return false;
            }
            let (next, _) = self
                .ack_cond
                .wait_timeout(acks, deadline.saturating_duration_since(now))
                .unwrap_or_else(|e| e.into_inner());
            acks = next;
        }
    }
}

/// State shared by every replica (and read by the HTTP layer).
pub struct SchedShared {
    /// Serving metrics registry.
    pub metrics: Arc<Metrics>,
    /// Windowed acceptance monitor (paper §7 alerting).
    pub monitor: Arc<AcceptanceMonitor>,
    /// The server's long-lived adaptive-γ controller, when enabled.
    pub controller: Option<Arc<Mutex<GammaController>>>,
    /// Per-kind learned draft-head snapshots, merged across replicas.
    pub draft_heads: Mutex<BTreeMap<DraftKind, Vec<f32>>>,
    /// Seeded fault-injection schedule, when chaos is armed (`None` in
    /// normal operation — the hot path never consults it).
    pub fault_plan: Option<Arc<FaultPlan>>,
    /// Flight recorder (`None` = tracing disabled — the hot path never
    /// touches it, mirroring `fault_plan`'s zero-cost gating).
    pub trace: Option<Arc<crate::trace::TraceSink>>,
}

impl SchedShared {
    /// Current head snapshot for `kind`, if any replica exported one.
    pub fn head_for(&self, kind: DraftKind) -> Option<Vec<f32>> {
        lock_ignore_poison(&self.draft_heads).get(&kind).cloned()
    }

    /// Fold a replica's exported head into the shared snapshot:
    /// elementwise mean with the stored head (deterministic, keeps every
    /// replica's adaptation represented), or replace it on a shape
    /// change.
    pub fn merge_head(&self, kind: DraftKind, head: Vec<f32>) {
        let mut hs = lock_ignore_poison(&self.draft_heads);
        match hs.get_mut(&kind) {
            Some(prev) if prev.len() == head.len() => {
                for (p, h) in prev.iter_mut().zip(&head) {
                    *p = 0.5 * (*p + *h);
                }
            }
            _ => {
                hs.insert(kind, head);
            }
        }
    }

    /// Drop a stored head (a replica found it stale/mis-shaped).
    pub fn discard_head(&self, kind: DraftKind) {
        lock_ignore_poison(&self.draft_heads).remove(&kind);
    }
}

/// Spawn `cfg.replicas` engine threads; blocks until every replica's
/// backends are loaded and warmed (or fails, after tearing the pool
/// down). Each thread drains the queue until shutdown.
pub fn start_pool(
    cfg: Arc<ServeConfig>,
    shape: ModelShape,
    slot: Arc<ModelSlot>,
    queue: Arc<AdmissionQueue>,
    shared: Arc<SchedShared>,
    stop: Arc<AtomicBool>,
) -> Result<Vec<std::thread::JoinHandle<()>>> {
    // Size the kernel compute pool before the first forward (first
    // initialization wins process-wide, exactly as the single-engine
    // loop did).
    let pool_size = if cfg.threads > 0 {
        crate::util::threadpool::init_global_pool(cfg.threads)
    } else {
        crate::util::threadpool::global_pool().size()
    };
    log::info!("kernel compute pool: {pool_size} threads");

    let (ready_tx, ready_rx) = mpsc::sync_channel::<Result<String, String>>(cfg.replicas);
    let mut handles = Vec::new();
    for r in 0..cfg.replicas {
        let cfg = Arc::clone(&cfg);
        let slot = Arc::clone(&slot);
        let queue = Arc::clone(&queue);
        let shared = Arc::clone(&shared);
        let stop = Arc::clone(&stop);
        let ready = ready_tx.clone();
        let handle = std::thread::Builder::new()
            .name(format!("stride-replica-{r}"))
            .spawn(move || {
                let (builder, generation) = slot.snapshot();
                let stacks = match builder(r) {
                    Ok(s) => s,
                    Err(e) => {
                        let _ = ready.send(Err(format!("replica {r}: {e:#}")));
                        return;
                    }
                };
                // Warm both stacks so the first request doesn't pay
                // first-touch cost.
                let warm = vec![0.0f32; shape.n_ctx * shape.patch];
                let _ = stacks.target.forward(&warm, shape.n_ctx);
                let _ = stacks.draft.forward(&warm, shape.n_ctx);
                slot.ack(r, generation);
                let _ = ready.send(Ok(format!(
                    "replica {r}: target={} draft={}",
                    stacks.target.name(),
                    stacks.draft.name()
                )));
                replica_main(r, &cfg, shape, stacks, generation, &slot, &queue, &shared, &stop);
            })
            .context("spawning replica thread")?;
        handles.push(handle);
    }
    drop(ready_tx);

    let mut failure: Option<String> = None;
    for _ in 0..cfg.replicas {
        match ready_rx.recv() {
            Ok(Ok(desc)) => log::info!("engine ready: {desc}"),
            Ok(Err(e)) => {
                failure = Some(e);
                break;
            }
            Err(_) => {
                failure = Some("replica thread died during startup".into());
                break;
            }
        }
    }
    if let Some(e) = failure {
        // Tear down whatever did come up before reporting the failure.
        queue.shutdown();
        stop.store(true, Ordering::Relaxed);
        for h in handles {
            let _ = h.join();
        }
        anyhow::bail!("engine startup failed: {e}");
    }
    Ok(handles)
}

/// Wrap a replica's stacks in the chaos decorator when a fault plan is
/// armed; a no-op (and no wrapper on the hot path) otherwise.
fn arm(stacks: ReplicaStacks, shared: &SchedShared) -> ReplicaStacks {
    let Some(plan) = &shared.fault_plan else { return stacks };
    ReplicaStacks {
        target: FaultyBackend::wrap(stacks.target, Arc::clone(plan), FaultSite::Target),
        draft: FaultyBackend::wrap(stacks.draft, Arc::clone(plan), FaultSite::Draft),
    }
}

/// Best-effort text of a panic payload (for logs and the typed
/// `replica_failure` reply).
fn panic_message(payload: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

/// Rebuild `stacks` from the slot's current builder (a swap landed).
/// On the native backend the builder clones `Arc` weight handles out of
/// the already-verified [`crate::registry::LoadedPair`], so a rebind
/// costs session/scratch construction, never a disk read. A failed
/// build keeps the prior stacks serving (the swap caller verified the
/// new weights load, so this is a replicate/alloc failure, not bad
/// bytes) — either way the generation is acknowledged so the swap
/// barrier cannot hang.
fn rebind(
    replica: usize,
    shape: ModelShape,
    stacks: &mut ReplicaStacks,
    slot: &ModelSlot,
    shared: &SchedShared,
) -> u64 {
    let (builder, generation) = slot.snapshot();
    match builder(replica) {
        Ok(fresh) => {
            // Same warm-up as startup: the first post-swap request
            // should not pay first-touch cost either.
            let warm = vec![0.0f32; shape.n_ctx * shape.patch];
            let _ = fresh.target.forward(&warm, shape.n_ctx);
            let _ = fresh.draft.forward(&warm, shape.n_ctx);
            *stacks = arm(fresh, shared);
            shared.metrics.inc("model_swap_rebinds", 1);
            log::info!("replica {replica} rebound to model generation {generation}");
        }
        Err(e) => {
            shared.metrics.inc("model_swap_rebind_failures", 1);
            log::error!(
                "replica {replica} failed to bind model generation {generation}, \
                 keeping prior stacks: {e:#}"
            );
        }
    }
    slot.ack(replica, generation);
    generation
}

#[allow(clippy::too_many_arguments)]
fn replica_main(
    replica: usize,
    cfg: &ServeConfig,
    shape: ModelShape,
    stacks: ReplicaStacks,
    generation: u64,
    slot: &ModelSlot,
    queue: &AdmissionQueue,
    shared: &SchedShared,
    stop: &AtomicBool,
) {
    let max_wait = Duration::from_millis(cfg.max_wait_ms);
    // Arm chaos only after the warm-up forwards, so startup cannot be
    // killed by its own injection schedule.
    let mut stacks = arm(stacks, shared);
    let mut generation = generation;
    loop {
        if stop.load(Ordering::Relaxed) {
            return;
        }
        // Swap check between batches: a decode group that was in flight
        // when the slot moved finishes on the old weights; nothing is
        // dropped.
        if slot.generation() != generation {
            generation = rebind(replica, shape, &mut stacks, slot, shared);
        }
        let (key, jobs) = match queue.next_batch_or_interrupt(
            replica,
            cfg.max_batch,
            max_wait,
            queue.epoch(),
        ) {
            NextBatch::Batch(key, jobs) => (key, jobs),
            // Epoch moved while parked: loop back to the rebind check.
            NextBatch::Interrupted => continue,
            NextBatch::Shutdown => return,
        };
        shared.metrics.inc("batches", 1);
        shared.metrics.inc("batched_jobs", jobs.len() as u64);
        shared.metrics.inc(&format!("replica_{replica}_batches"), 1);
        let run = GroupRun::new(jobs);
        let outcome = catch_unwind(AssertUnwindSafe(|| {
            execute_batch(
                cfg,
                shape,
                stacks.target.as_ref(),
                stacks.draft.as_ref(),
                key,
                &run,
                shared,
                replica,
            );
        }));
        if let Err(payload) = outcome {
            let msg = panic_message(payload.as_ref());
            log::error!("replica {replica} panicked mid-group, restarting: {msg}");
            shared.metrics.inc("replica_restarts", 1);
            if let Some(t) = &shared.trace {
                t.record(0, crate::trace::EventKind::ReplicaRestart { replica: replica as u32 });
            }
            run.recover_after_panic(key, queue, shared, &msg, replica);
            // Rebind to the shared weight store: on the native backend
            // `replicate()` clones `Arc` handles, so a restart costs
            // session state, never a weight reload. Snapshotting from
            // the slot means a restart concurrent with a swap comes
            // back on the *new* weights.
            let (builder, gen) = slot.snapshot();
            match builder(replica) {
                Ok(fresh) => {
                    stacks = arm(fresh, shared);
                    if gen != generation {
                        slot.ack(replica, gen);
                        generation = gen;
                    }
                }
                Err(e) => log::error!(
                    "replica {replica} stack rebuild failed, keeping prior stacks: {e:#}"
                ),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn unused_builder() -> ReplicaBuilder {
        Arc::new(|_| anyhow::bail!("slot tests never build stacks"))
    }

    #[test]
    fn slot_swap_advances_generation_and_identity() {
        let slot = ModelSlot::new(unused_builder(), "unregistered", "boot");
        assert_eq!(slot.generation(), 0);
        assert_eq!(slot.digest(), "unregistered");
        let g = slot.swap(unused_builder(), "abc123", "m:v2");
        assert_eq!(g, 1);
        assert_eq!(slot.generation(), 1);
        assert_eq!(slot.digest(), "abc123");
        assert_eq!(slot.label(), "m:v2");
        let (_, snap_gen) = slot.snapshot();
        assert_eq!(snap_gen, 1);
    }

    #[test]
    fn swap_barrier_waits_for_every_replica_and_times_out_on_stragglers() {
        let slot = Arc::new(ModelSlot::new(unused_builder(), "d0", "boot"));
        slot.ack(0, 0);
        slot.ack(1, 0);
        assert!(slot.wait_generation(0, 2, Duration::ZERO));
        let gen = slot.swap(unused_builder(), "d1", "m:v2");
        // Nobody has rebound yet: the barrier must time out, not hang.
        assert!(!slot.wait_generation(gen, 2, Duration::from_millis(20)));
        assert_eq!(slot.replicas_at(gen), 0);
        // Replicas acknowledge from their own threads; the barrier
        // releases once the last one lands.
        let s2 = Arc::clone(&slot);
        let t = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(20));
            s2.ack(0, gen);
            std::thread::sleep(Duration::from_millis(20));
            s2.ack(1, gen);
        });
        assert!(slot.wait_generation(gen, 2, Duration::from_secs(5)));
        t.join().unwrap();
        assert_eq!(slot.replicas_at(gen), 2);
        // Acks are monotone: a late ack for an old generation does not
        // regress the barrier.
        slot.ack(0, 0);
        assert_eq!(slot.replicas_at(gen), 2);
    }
}
