//! JSON wire protocol of the forecasting service.
//!
//! POST /forecast
//!   {"history": [f32...], "horizon": <patches>, "gamma"?: n, "k"?: n,
//!    "sigma"?: x,
//!    "mode"?: "sd" | "baseline" | "draft", "dataset"?: "etth1",
//!    "cache"?: true|false, "adaptive"?: true|false,
//!    "draft"?: "model" | "extrap" | "adaptive",
//!    "priority"?: "high" | "normal" | "low", "deadline_ms"?: n,
//!    "seed"?: n, "request_id"?: "<hex>" | n}
//! ->
//!   {"forecast": [f32...], "mode": "...", "draft": "...",
//!    "priority": "...", "replica": n, "seed": n, "request_id": "<hex>",
//!    "latency_ms": x, "alpha_hat": x, "mean_block_len": x, "rounds": n,
//!    "draft_calls": n, "target_calls": n}
//!
//! Every request carries a `request_id` (assigned by the scheduler when
//! the client doesn't supply one via the JSON field or the
//! `X-Request-Id` header) that is echoed in the response body, the
//! `X-Request-Id` response header, typed error bodies, and every flight-
//! recorder trace event ([`crate::trace`]) — the join key between a
//! client-observed outcome and its server-side timeline.
//!
//! Error responses carry a machine-readable `error_code` alongside the
//! human `error` message (see [`ServeError`]): `shed` (HTTP 429 with a
//! `Retry-After` header), `deadline_expired` (HTTP 504 — the job was
//! never decoded), `invalid` (HTTP 400), `internal` (HTTP 500),
//! `replica_failure` (HTTP 500 — the executing replica panicked and was
//! restarted), `draining` (HTTP 503 — the server is shutting down
//! gracefully and no longer admits work), `digest_mismatch` (HTTP 422 —
//! a registry blob's bytes do not hash to the promised digest),
//! `not_found` (HTTP 404 — unknown registry manifest/blob/model), and
//! `body_too_large` (HTTP 413 — request body over `max_body_bytes`).

use anyhow::{bail, Context, Result};

use crate::specdec::DraftKind;
use crate::util::json::Json;

/// Scheduling priority of one request. The admission queue orders each
/// compatibility group by priority band first (EDF within a band), and a
/// saturated queue evicts its worst low-priority entry to admit a
/// higher-priority arrival.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, PartialOrd, Ord)]
pub enum Priority {
    /// Shed first, served last.
    Low,
    /// The default band.
    #[default]
    Normal,
    /// Latency-sensitive traffic: admitted preferentially, served first.
    High,
}

impl Priority {
    /// Wire name of the band (`"low"` / `"normal"` / `"high"`).
    pub fn as_str(&self) -> &'static str {
        match self {
            Priority::Low => "low",
            Priority::Normal => "normal",
            Priority::High => "high",
        }
    }

    /// Parse a wire name; `None` for unknown spellings.
    pub fn parse(s: &str) -> Option<Priority> {
        match s {
            "low" => Some(Priority::Low),
            "normal" => Some(Priority::Normal),
            "high" => Some(Priority::High),
            _ => None,
        }
    }

    /// All bands, lowest first (per-band metrics iterate this).
    pub fn all() -> [Priority; 3] {
        [Priority::Low, Priority::Normal, Priority::High]
    }
}

/// A typed serving failure: every variant maps to a distinct wire
/// `error_code` and HTTP status, so load balancers and clients can react
/// mechanically (back off on `shed`, drop on `deadline_expired`, fix the
/// request on `invalid`).
#[derive(Clone, Debug, PartialEq)]
pub enum ServeError {
    /// The bounded admission queue is saturated and this job was shed —
    /// either rejected at the door or evicted by a higher-priority
    /// arrival. HTTP 429 with a `Retry-After` hint.
    Shed {
        /// Suggested client back-off before retrying.
        retry_after_ms: u64,
    },
    /// The request's `deadline_ms` elapsed while it was still queued; it
    /// was failed fast and **never decoded**. HTTP 504.
    DeadlineExpired {
        /// The deadline the request carried.
        deadline_ms: u64,
        /// How long the job had waited when it was purged.
        waited_ms: u64,
    },
    /// The request failed validation. HTTP 400.
    Invalid(String),
    /// The decode (or the engine) failed. HTTP 500.
    Internal(String),
    /// The replica executing this job panicked. The job was answered by
    /// the supervisor (not silently dropped) and the replica's stacks
    /// were rebuilt over the shared packed weights. HTTP 500 with a
    /// distinct code so clients can distinguish "my request is poison /
    /// unlucky" from generic engine failure.
    ReplicaFailure(String),
    /// The server is draining ahead of shutdown: in-flight and queued
    /// jobs still complete, but new work is refused. HTTP 503.
    Draining,
    /// A registry blob's bytes hash to something other than the digest
    /// the manifest (or its content address) promised — a corrupt,
    /// truncated, or tampered artifact. The blob is rejected and never
    /// loaded; this is always a typed error, never a panic or a served
    /// NaN. HTTP 422.
    DigestMismatch {
        /// The digest the caller asked for.
        expected: String,
        /// The digest the bytes actually hash to.
        actual: String,
    },
    /// A registry manifest, blob, or model reference does not exist.
    /// HTTP 404.
    NotFound(String),
    /// A request body exceeded the server's `max_body_bytes` cap. The
    /// HTTP layer normally answers this before the handler runs; the
    /// variant exists so registry handlers can enforce tighter per-route
    /// caps with the same wire shape. HTTP 413.
    BodyTooLarge {
        /// The declared/observed body size.
        got: usize,
        /// The enforced cap.
        limit: usize,
    },
}

impl ServeError {
    /// Machine-readable wire code (`shed` / `deadline_expired` /
    /// `invalid` / `internal` / `replica_failure` / `draining`).
    pub fn code(&self) -> &'static str {
        match self {
            ServeError::Shed { .. } => "shed",
            ServeError::DeadlineExpired { .. } => "deadline_expired",
            ServeError::Invalid(_) => "invalid",
            ServeError::Internal(_) => "internal",
            ServeError::ReplicaFailure(_) => "replica_failure",
            ServeError::Draining => "draining",
            ServeError::DigestMismatch { .. } => "digest_mismatch",
            ServeError::NotFound(_) => "not_found",
            ServeError::BodyTooLarge { .. } => "body_too_large",
        }
    }

    /// The HTTP status this error is served with.
    pub fn http_status(&self) -> u16 {
        match self {
            ServeError::Shed { .. } => 429,
            ServeError::DeadlineExpired { .. } => 504,
            ServeError::Invalid(_) => 400,
            ServeError::Internal(_) => 500,
            ServeError::ReplicaFailure(_) => 500,
            ServeError::Draining => 503,
            ServeError::DigestMismatch { .. } => 422,
            ServeError::NotFound(_) => 404,
            ServeError::BodyTooLarge { .. } => 413,
        }
    }

    /// Wire body: `{"error": ..., "error_code": ...}` plus
    /// variant-specific fields (`retry_after_ms`, `deadline_ms`,
    /// `waited_ms`).
    pub fn to_json(&self) -> Json {
        let mut fields = vec![
            ("error", Json::from(self.to_string())),
            ("error_code", Json::from(self.code())),
        ];
        match self {
            ServeError::Shed { retry_after_ms } => {
                fields.push(("retry_after_ms", Json::from(*retry_after_ms as usize)));
            }
            ServeError::DeadlineExpired { deadline_ms, waited_ms } => {
                fields.push(("deadline_ms", Json::from(*deadline_ms as usize)));
                fields.push(("waited_ms", Json::from(*waited_ms as usize)));
            }
            ServeError::DigestMismatch { expected, actual } => {
                fields.push(("expected", Json::from(expected.as_str())));
                fields.push(("actual", Json::from(actual.as_str())));
            }
            ServeError::BodyTooLarge { got, limit } => {
                fields.push(("got", Json::from(*got)));
                fields.push(("max_body_bytes", Json::from(*limit)));
            }
            _ => {}
        }
        Json::obj(fields)
    }

    /// [`ServeError::to_json`] with the owning request's id stamped in
    /// (`"request_id": "<16-hex>"`), so error bodies join against the
    /// flight-recorder timeline exactly like successes. `rid` 0 (no id
    /// assigned yet, e.g. a body that failed to parse) stamps nothing.
    pub fn to_json_with_request_id(&self, rid: u64) -> Json {
        let j = self.to_json();
        if rid == 0 {
            return j;
        }
        match j {
            Json::Obj(mut m) => {
                m.insert(
                    "request_id".to_string(),
                    Json::from(crate::trace::format_request_id(rid)),
                );
                Json::Obj(m)
            }
            other => other,
        }
    }
}

impl std::fmt::Display for ServeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ServeError::Shed { retry_after_ms } => write!(
                f,
                "admission queue saturated; retry after {retry_after_ms} ms"
            ),
            ServeError::DeadlineExpired { deadline_ms, waited_ms } => write!(
                f,
                "deadline of {deadline_ms} ms expired after waiting {waited_ms} ms; \
                 request was not decoded"
            ),
            ServeError::Invalid(m) => write!(f, "{m}"),
            ServeError::Internal(m) => write!(f, "{m}"),
            ServeError::ReplicaFailure(m) => {
                write!(f, "replica failed while executing this request: {m}")
            }
            ServeError::Draining => {
                write!(f, "server is draining ahead of shutdown; not admitting new work")
            }
            ServeError::DigestMismatch { expected, actual } => write!(
                f,
                "digest mismatch: expected sha256:{expected}, bytes hash to sha256:{actual}"
            ),
            ServeError::NotFound(what) => write!(f, "not found: {what}"),
            ServeError::BodyTooLarge { got, limit } => {
                write!(f, "request body of {got} bytes exceeds the {limit}-byte limit")
            }
        }
    }
}

impl std::error::Error for ServeError {}

/// Decoding mode of one forecast request.
#[derive(Clone, Debug, PartialEq)]
pub enum Mode {
    /// Speculative decoding (the default).
    Sd,
    /// Target-only autoregression (the A/B baseline).
    Baseline,
    /// Draft-only autoregression (cost-ratio probes).
    DraftOnly,
}

impl Mode {
    /// Wire name of the mode (`"sd"` / `"baseline"` / `"draft"`).
    pub fn as_str(&self) -> &'static str {
        match self {
            Mode::Sd => "sd",
            Mode::Baseline => "baseline",
            Mode::DraftOnly => "draft",
        }
    }
}

/// One parsed `/forecast` request body.
#[derive(Clone, Debug)]
pub struct ForecastRequest {
    /// Normalized history values; length must be a multiple of the patch.
    pub history: Vec<f32>,
    /// Forecast horizon in patches.
    pub horizon: usize,
    /// Decoding mode (`sd` unless overridden).
    pub mode: Mode,
    /// Optional per-request overrides.
    pub gamma: Option<usize>,
    /// Per-request tree branch-count override (None = server config).
    /// `1` pins the classic single-trajectory decode; `k > 1` routes the
    /// job to a per-job tree decode (`specdec::sd_generate_tree_from`)
    /// drafting k candidate branches per round. Like `gamma`, an explicit
    /// `k` pins the request to the static path — the server's joint
    /// (γ × k) controller only drives requests that leave both unset.
    pub k: Option<usize>,
    /// Per-request acceptance-width override (None = server config).
    pub sigma: Option<f64>,
    /// Per-request KV-cache override (None = server config). Exposed so
    /// A/B latency probes can hit both cost models on one running server.
    pub cache: Option<bool>,
    /// Per-request adaptive-speculation override (None = server config).
    /// `true` routes the job through the server's live γ controller (an
    /// error when the server runs without one); `false` pins the static
    /// γ. An explicit `gamma` always wins over adaptation — a pinned
    /// request is a pinned request.
    pub adaptive: Option<bool>,
    /// Per-request draft-source override (None = server config):
    /// `"model"` pins the classic two-model draft, `"extrap"` the
    /// draft-free continuation, `"adaptive"` the online-learned head.
    /// SD jobs group by draft kind, so mixed traffic batches cleanly.
    /// Overriding the kind routes the job to the *static*-γ path: the
    /// server's long-lived γ controller is tuned per-source, so an
    /// explicit `"adaptive": true` combined with a different kind is
    /// rejected rather than cross-contaminating its c/α̂ estimates.
    pub draft: Option<DraftKind>,
    /// Traffic-segment tag for acceptance monitoring (paper §7).
    pub dataset: Option<String>,
    /// Scheduling priority band (`normal` unless overridden). Orders the
    /// admission queue and decides who is evicted under saturation.
    pub priority: Priority,
    /// Soft deadline in milliseconds, measured from admission. Expired
    /// jobs are failed fast with [`ServeError::DeadlineExpired`] and
    /// never decoded; within a compatibility group, jobs dispatch
    /// earliest-deadline-first. `None` falls back to the server's
    /// `default_deadline_ms` (0 = no deadline).
    pub deadline_ms: Option<u64>,
    /// Per-request decode seed. With a pinned seed the response is a
    /// pure function of the request — bit-identical to
    /// `sd_generate_from` at that seed regardless of batching, replica
    /// count, or arrival order. `None` makes the scheduler assign a
    /// fresh seed (echoed in the response), so unseeded traffic keeps
    /// independent RNG streams: repeated `"sampled"` requests draw
    /// fresh samples, not copies.
    pub seed: Option<u64>,
    /// Client-supplied request id override (wire form: 1–16 hex digits,
    /// or a plain nonzero integer). `None` makes the scheduler assign a
    /// seeded, deterministic-under-`--seed` id at admission. Either way
    /// the id is echoed in the response body, the `X-Request-Id` header,
    /// typed errors, and every trace event. Id 0 is reserved for the
    /// control plane and rejected.
    pub request_id: Option<u64>,
}

impl ForecastRequest {
    /// Parse and validate a request from its JSON body.
    pub fn from_json(j: &Json) -> Result<ForecastRequest> {
        let history: Vec<f32> = j
            .get("history")
            .and_then(Json::as_arr)
            .context("'history' array required")?
            .iter()
            .map(|v| v.as_f64().map(|x| x as f32).context("history values must be numbers"))
            .collect::<Result<_>>()?;
        if history.is_empty() {
            bail!("'history' must be non-empty");
        }
        // Numeric guard at the door: NaN/inf history would flow straight
        // into session prefill and poison every downstream mean. JSON
        // cannot spell non-finite literals, but permissive parsers
        // (ours included: 1e999 overflows to inf) can still produce
        // them — reject here with a 400 instead of decoding garbage.
        if let Some(pos) = history.iter().position(|v| !v.is_finite()) {
            bail!("'history' contains a non-finite value at index {pos}");
        }
        let horizon = j.get("horizon").and_then(Json::as_usize).context("'horizon' required")?;
        if horizon == 0 || horizon > 1024 {
            bail!("'horizon' must be in [1, 1024] patches");
        }
        let mode = match j.get("mode").and_then(Json::as_str) {
            None | Some("sd") => Mode::Sd,
            Some("baseline") => Mode::Baseline,
            Some("draft") => Mode::DraftOnly,
            Some(other) => bail!("unknown mode '{other}'"),
        };
        let gamma = j.get("gamma").and_then(Json::as_usize);
        if let Some(g) = gamma {
            if g == 0 || g > 64 {
                bail!("'gamma' must be in [1, 64]");
            }
        }
        let k = j.get("k").and_then(Json::as_usize);
        if let Some(kv) = k {
            if kv == 0 || kv > crate::specdec::MAX_TREE_K {
                bail!("'k' must be in [1, {}]", crate::specdec::MAX_TREE_K);
            }
        }
        let sigma = j.get("sigma").and_then(Json::as_f64);
        if let Some(s) = sigma {
            if !(s > 0.0 && s < 100.0) {
                bail!("'sigma' must be in (0, 100)");
            }
        }
        let draft = match j.get("draft").and_then(Json::as_str) {
            None => None,
            Some(s) => Some(
                DraftKind::parse(s)
                    .with_context(|| format!("unknown draft kind '{s}' (model|extrap|adaptive)"))?,
            ),
        };
        let priority = match j.get("priority") {
            None => Priority::Normal,
            Some(v) => {
                let s = v.as_str().context("'priority' must be a string")?;
                Priority::parse(s)
                    .with_context(|| format!("unknown priority '{s}' (high|normal|low)"))?
            }
        };
        let deadline_ms = match j.get("deadline_ms") {
            None => None,
            Some(v) => {
                let d = v.as_usize().context("'deadline_ms' must be an integer")? as u64;
                if d == 0 || d > 3_600_000 {
                    bail!("'deadline_ms' must be in [1, 3600000]");
                }
                Some(d)
            }
        };
        let seed = match j.get("seed") {
            None => None,
            Some(v) => Some(v.as_usize().context("'seed' must be an integer")? as u64),
        };
        let request_id = match j.get("request_id") {
            None => None,
            Some(Json::Str(s)) => Some(
                crate::trace::parse_request_id(s)
                    .with_context(|| format!("'request_id' must be 1-16 nonzero hex digits, got '{s}'"))?,
            ),
            Some(v) => {
                let n = v.as_usize().context("'request_id' must be a hex string or integer")?;
                if n == 0 {
                    bail!("'request_id' 0 is reserved");
                }
                Some(n as u64)
            }
        };
        Ok(ForecastRequest {
            history,
            horizon,
            mode,
            gamma,
            k,
            sigma,
            cache: j.get("cache").and_then(Json::as_bool),
            adaptive: j.get("adaptive").and_then(Json::as_bool),
            draft,
            dataset: j.get("dataset").and_then(Json::as_str).map(String::from),
            priority,
            deadline_ms,
            seed,
            request_id,
        })
    }
}

/// One `/forecast` response body.
#[derive(Clone, Debug, Default)]
pub struct ForecastResponse {
    /// Forecast values, flat `[horizon * patch]`.
    pub forecast: Vec<f32>,
    /// Mode actually served (`"sd"` / `"baseline"` / `"draft"`).
    pub mode: String,
    /// Draft source that produced the proposals (`"model"` / `"extrap"`
    /// / `"adaptive"`; empty for the AR modes, which draft nothing).
    pub draft: String,
    /// Priority band the scheduler served this request in.
    pub priority: String,
    /// Replica that executed the decode (0-based; diagnostics only —
    /// responses are replica-invariant at a fixed seed).
    pub replica: usize,
    /// The decode seed actually used (the request's pinned seed, or the
    /// fresh one the scheduler assigned). Resubmitting the same request
    /// with `"seed"` set to this value replays the forecast exactly.
    pub seed: u64,
    /// The request's id (assigned or client-supplied), the join key for
    /// `GET /debug/requests/<id>` and the flight-recorder timeline.
    /// Serialized as 16 lowercase hex digits.
    pub request_id: u64,
    /// End-to-end request latency in milliseconds.
    pub latency_ms: f64,
    /// Mean acceptance probability of this decode (NaN for AR modes).
    pub alpha_hat: f64,
    /// Mean emitted patches per round (NaN for AR modes).
    pub mean_block_len: f64,
    /// Speculative rounds (or AR steps) executed.
    pub rounds: usize,
    /// Draft forward passes consumed.
    pub draft_calls: usize,
    /// Target forward passes consumed.
    pub target_calls: usize,
}

impl ForecastResponse {
    /// Serialize to the wire JSON (non-finite stats become `null`).
    pub fn to_json(&self) -> Json {
        fn num(v: f64) -> Json {
            if v.is_finite() {
                Json::Num(v)
            } else {
                Json::Null
            }
        }
        Json::obj(vec![
            ("forecast", Json::arr_f32(&self.forecast)),
            ("mode", Json::from(self.mode.as_str())),
            ("draft", Json::from(self.draft.as_str())),
            ("priority", Json::from(self.priority.as_str())),
            ("replica", Json::from(self.replica)),
            ("seed", Json::from(self.seed as usize)),
            ("request_id", Json::from(crate::trace::format_request_id(self.request_id))),
            ("latency_ms", num(self.latency_ms)),
            ("alpha_hat", num(self.alpha_hat)),
            ("mean_block_len", num(self.mean_block_len)),
            ("rounds", Json::from(self.rounds)),
            ("draft_calls", Json::from(self.draft_calls)),
            ("target_calls", Json::from(self.target_calls)),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_minimal_request() {
        let j = Json::parse(r#"{"history": [1.0, 2.0], "horizon": 4}"#).unwrap();
        let r = ForecastRequest::from_json(&j).unwrap();
        assert_eq!(r.history, vec![1.0, 2.0]);
        assert_eq!(r.horizon, 4);
        assert_eq!(r.mode, Mode::Sd);
        assert!(r.gamma.is_none());
        assert!(r.k.is_none());
    }

    #[test]
    fn parses_k_override() {
        let j = Json::parse(r#"{"history": [0.5], "horizon": 2, "k": 4}"#).unwrap();
        assert_eq!(ForecastRequest::from_json(&j).unwrap().k, Some(4));
        for bad in [
            r#"{"history": [0.5], "horizon": 2, "k": 0}"#,
            r#"{"history": [0.5], "horizon": 2, "k": 17}"#,
        ] {
            let j = Json::parse(bad).unwrap();
            assert!(ForecastRequest::from_json(&j).is_err(), "should reject {bad}");
        }
    }

    #[test]
    fn parses_full_request() {
        let j = Json::parse(
            r#"{"history": [0.5], "horizon": 2, "mode": "baseline", "gamma": 5,
                "sigma": 0.7, "dataset": "etth1"}"#,
        )
        .unwrap();
        let r = ForecastRequest::from_json(&j).unwrap();
        assert_eq!(r.mode, Mode::Baseline);
        assert_eq!(r.gamma, Some(5));
        assert_eq!(r.dataset.as_deref(), Some("etth1"));
        assert_eq!(r.adaptive, None);
        assert_eq!(r.draft, None);
    }

    #[test]
    fn parses_draft_override() {
        let j = Json::parse(r#"{"history": [0.5], "horizon": 2, "draft": "extrap"}"#).unwrap();
        assert_eq!(ForecastRequest::from_json(&j).unwrap().draft, Some(DraftKind::Extrap));
        let j = Json::parse(r#"{"history": [0.5], "horizon": 2, "draft": "adaptive"}"#).unwrap();
        assert_eq!(ForecastRequest::from_json(&j).unwrap().draft, Some(DraftKind::Adaptive));
        let j = Json::parse(r#"{"history": [0.5], "horizon": 2, "draft": "warp"}"#).unwrap();
        assert!(ForecastRequest::from_json(&j).is_err());
    }

    #[test]
    fn parses_adaptive_override() {
        let j = Json::parse(r#"{"history": [0.5], "horizon": 2, "adaptive": true}"#).unwrap();
        assert_eq!(ForecastRequest::from_json(&j).unwrap().adaptive, Some(true));
        let j = Json::parse(r#"{"history": [0.5], "horizon": 2, "adaptive": false}"#).unwrap();
        assert_eq!(ForecastRequest::from_json(&j).unwrap().adaptive, Some(false));
    }

    #[test]
    fn rejects_bad_requests() {
        for bad in [
            r#"{"horizon": 4}"#,
            r#"{"history": [], "horizon": 4}"#,
            r#"{"history": [1], "horizon": 0}"#,
            r#"{"history": [1], "horizon": 4, "mode": "warp"}"#,
            r#"{"history": [1], "horizon": 4, "gamma": 0}"#,
            r#"{"history": [1], "horizon": 4, "sigma": -1}"#,
            r#"{"history": ["x"], "horizon": 4}"#,
        ] {
            let j = Json::parse(bad).unwrap();
            assert!(ForecastRequest::from_json(&j).is_err(), "should reject {bad}");
        }
    }

    #[test]
    fn rejects_non_finite_history() {
        // Rust's f64 parser saturates huge exponents to infinity, so a
        // permissive client can smuggle inf through syntactically valid
        // JSON. The parse guard must turn that into a 400, not a decode.
        let j = Json::parse(r#"{"history": [1.0, 1e999, 2.0], "horizon": 4}"#).unwrap();
        let err = ForecastRequest::from_json(&j).unwrap_err();
        assert!(err.to_string().contains("non-finite"), "got: {err:#}");
        assert!(err.to_string().contains("index 1"), "got: {err:#}");
        let j = Json::parse(r#"{"history": [-1e999], "horizon": 4}"#).unwrap();
        assert!(ForecastRequest::from_json(&j).is_err());
        // Hand-built NaN (unreachable via the wire parser, but the guard
        // must still hold for programmatic construction).
        let j = Json::obj(vec![
            ("history", Json::Arr(vec![Json::Num(f64::NAN)])),
            ("horizon", Json::Num(4.0)),
        ]);
        assert!(ForecastRequest::from_json(&j).is_err());
    }

    #[test]
    fn response_roundtrips() {
        let resp = ForecastResponse {
            forecast: vec![1.0, 2.0],
            mode: "sd".into(),
            draft: "model".into(),
            priority: "high".into(),
            replica: 3,
            seed: 99,
            request_id: 0xabc1,
            latency_ms: 3.5,
            alpha_hat: 0.97,
            mean_block_len: 3.4,
            rounds: 2,
            draft_calls: 6,
            target_calls: 2,
        };
        let j = resp.to_json();
        let parsed = Json::parse(&j.to_string()).unwrap();
        assert_eq!(parsed.get("mode").unwrap().as_str(), Some("sd"));
        assert_eq!(parsed.get("draft").unwrap().as_str(), Some("model"));
        assert_eq!(parsed.get("priority").unwrap().as_str(), Some("high"));
        assert_eq!(parsed.get("replica").unwrap().as_usize(), Some(3));
        assert_eq!(parsed.get("seed").unwrap().as_usize(), Some(99));
        assert_eq!(parsed.get("request_id").unwrap().as_str(), Some("000000000000abc1"));
        assert_eq!(parsed.get("rounds").unwrap().as_usize(), Some(2));
        assert_eq!(parsed.get("forecast").unwrap().as_arr().unwrap().len(), 2);
    }

    #[test]
    fn parses_scheduling_fields() {
        let j = Json::parse(
            r#"{"history": [0.5], "horizon": 2, "priority": "high",
                "deadline_ms": 250, "seed": 42}"#,
        )
        .unwrap();
        let r = ForecastRequest::from_json(&j).unwrap();
        assert_eq!(r.priority, Priority::High);
        assert_eq!(r.deadline_ms, Some(250));
        assert_eq!(r.seed, Some(42));
        // Defaults.
        let j = Json::parse(r#"{"history": [0.5], "horizon": 2}"#).unwrap();
        let r = ForecastRequest::from_json(&j).unwrap();
        assert_eq!(r.priority, Priority::Normal);
        assert_eq!(r.deadline_ms, None);
        assert_eq!(r.seed, None);
        // Rejections.
        for bad in [
            r#"{"history": [0.5], "horizon": 2, "priority": "urgent"}"#,
            r#"{"history": [0.5], "horizon": 2, "priority": 7}"#,
            r#"{"history": [0.5], "horizon": 2, "deadline_ms": 0}"#,
            r#"{"history": [0.5], "horizon": 2, "deadline_ms": 4000000}"#,
            r#"{"history": [0.5], "horizon": 2, "seed": "abc"}"#,
        ] {
            let j = Json::parse(bad).unwrap();
            assert!(ForecastRequest::from_json(&j).is_err(), "should reject {bad}");
        }
    }

    #[test]
    fn parses_request_id_override() {
        let j = Json::parse(r#"{"history": [0.5], "horizon": 2, "request_id": "00ff"}"#).unwrap();
        assert_eq!(ForecastRequest::from_json(&j).unwrap().request_id, Some(255));
        let j = Json::parse(r#"{"history": [0.5], "horizon": 2, "request_id": 77}"#).unwrap();
        assert_eq!(ForecastRequest::from_json(&j).unwrap().request_id, Some(77));
        let j = Json::parse(r#"{"history": [0.5], "horizon": 2}"#).unwrap();
        assert_eq!(ForecastRequest::from_json(&j).unwrap().request_id, None);
        for bad in [
            r#"{"history": [0.5], "horizon": 2, "request_id": "zz"}"#,
            r#"{"history": [0.5], "horizon": 2, "request_id": "0"}"#,
            r#"{"history": [0.5], "horizon": 2, "request_id": 0}"#,
            r#"{"history": [0.5], "horizon": 2, "request_id": true}"#,
        ] {
            let j = Json::parse(bad).unwrap();
            assert!(ForecastRequest::from_json(&j).is_err(), "should reject {bad}");
        }
    }

    #[test]
    fn error_bodies_stamp_request_id() {
        let e = ServeError::Shed { retry_after_ms: 10 };
        let j = e.to_json_with_request_id(0x2a);
        assert_eq!(j.get("request_id").unwrap().as_str(), Some("000000000000002a"));
        assert_eq!(j.get("error_code").unwrap().as_str(), Some("shed"));
        // No id assigned yet (e.g. the body never parsed): no stamp.
        let j = e.to_json_with_request_id(0);
        assert!(j.get("request_id").is_none());
    }

    #[test]
    fn priority_ordering_and_names() {
        assert!(Priority::High > Priority::Normal);
        assert!(Priority::Normal > Priority::Low);
        for p in Priority::all() {
            assert_eq!(Priority::parse(p.as_str()), Some(p));
        }
        assert_eq!(Priority::parse("urgent"), None);
    }

    #[test]
    fn serve_error_wire_mapping() {
        let e = ServeError::Shed { retry_after_ms: 750 };
        assert_eq!(e.http_status(), 429);
        assert_eq!(e.code(), "shed");
        let j = e.to_json();
        assert_eq!(j.get("error_code").unwrap().as_str(), Some("shed"));
        assert_eq!(j.get("retry_after_ms").unwrap().as_usize(), Some(750));

        let e = ServeError::DeadlineExpired { deadline_ms: 100, waited_ms: 180 };
        assert_eq!(e.http_status(), 504);
        assert_eq!(e.code(), "deadline_expired");
        let j = e.to_json();
        assert_eq!(j.get("deadline_ms").unwrap().as_usize(), Some(100));
        assert_eq!(j.get("waited_ms").unwrap().as_usize(), Some(180));

        assert_eq!(ServeError::Invalid("x".into()).http_status(), 400);
        assert_eq!(ServeError::Internal("x".into()).http_status(), 500);
        assert!(ServeError::Invalid("bad gamma".into()).to_string().contains("bad gamma"));

        let e = ServeError::ReplicaFailure("injected fault: panic".into());
        assert_eq!(e.http_status(), 500);
        assert_eq!(e.code(), "replica_failure");
        let j = e.to_json();
        assert_eq!(j.get("error_code").unwrap().as_str(), Some("replica_failure"));
        assert!(e.to_string().contains("replica failed"));

        let e = ServeError::Draining;
        assert_eq!(e.http_status(), 503);
        assert_eq!(e.code(), "draining");
        assert_eq!(e.to_json().get("error_code").unwrap().as_str(), Some("draining"));

        let e = ServeError::DigestMismatch { expected: "ab".into(), actual: "cd".into() };
        assert_eq!(e.http_status(), 422);
        assert_eq!(e.code(), "digest_mismatch");
        let j = e.to_json();
        assert_eq!(j.get("expected").unwrap().as_str(), Some("ab"));
        assert_eq!(j.get("actual").unwrap().as_str(), Some("cd"));
        assert!(e.to_string().contains("digest mismatch"));

        let e = ServeError::NotFound("model demo:v2".into());
        assert_eq!(e.http_status(), 404);
        assert_eq!(e.code(), "not_found");
        assert!(e.to_string().contains("demo:v2"));

        let e = ServeError::BodyTooLarge { got: 2048, limit: 1024 };
        assert_eq!(e.http_status(), 413);
        assert_eq!(e.code(), "body_too_large");
        let j = e.to_json();
        assert_eq!(j.get("got").unwrap().as_usize(), Some(2048));
        assert_eq!(j.get("max_body_bytes").unwrap().as_usize(), Some(1024));
    }

    #[test]
    fn nan_stats_serialize_as_null() {
        let resp = ForecastResponse { alpha_hat: f64::NAN, ..Default::default() };
        let j = resp.to_json();
        assert_eq!(j.get("alpha_hat"), Some(&Json::Null));
    }
}
