//! JSON wire protocol of the forecasting service.
//!
//! POST /forecast
//!   {"history": [f32...], "horizon": <patches>, "gamma"?: n, "sigma"?: x,
//!    "mode"?: "sd" | "baseline" | "draft", "dataset"?: "etth1",
//!    "cache"?: true|false, "adaptive"?: true|false,
//!    "draft"?: "model" | "extrap" | "adaptive"}
//! ->
//!   {"forecast": [f32...], "mode": "...", "draft": "...",
//!    "latency_ms": x, "alpha_hat": x, "mean_block_len": x, "rounds": n,
//!    "draft_calls": n, "target_calls": n}

use anyhow::{bail, Context, Result};

use crate::specdec::DraftKind;
use crate::util::json::Json;

/// Decoding mode of one forecast request.
#[derive(Clone, Debug, PartialEq)]
pub enum Mode {
    /// Speculative decoding (the default).
    Sd,
    /// Target-only autoregression (the A/B baseline).
    Baseline,
    /// Draft-only autoregression (cost-ratio probes).
    DraftOnly,
}

impl Mode {
    /// Wire name of the mode (`"sd"` / `"baseline"` / `"draft"`).
    pub fn as_str(&self) -> &'static str {
        match self {
            Mode::Sd => "sd",
            Mode::Baseline => "baseline",
            Mode::DraftOnly => "draft",
        }
    }
}

/// One parsed `/forecast` request body.
#[derive(Clone, Debug)]
pub struct ForecastRequest {
    /// Normalized history values; length must be a multiple of the patch.
    pub history: Vec<f32>,
    /// Forecast horizon in patches.
    pub horizon: usize,
    /// Decoding mode (`sd` unless overridden).
    pub mode: Mode,
    /// Optional per-request overrides.
    pub gamma: Option<usize>,
    /// Per-request acceptance-width override (None = server config).
    pub sigma: Option<f64>,
    /// Per-request KV-cache override (None = server config). Exposed so
    /// A/B latency probes can hit both cost models on one running server.
    pub cache: Option<bool>,
    /// Per-request adaptive-speculation override (None = server config).
    /// `true` routes the job through the server's live γ controller (an
    /// error when the server runs without one); `false` pins the static
    /// γ. An explicit `gamma` always wins over adaptation — a pinned
    /// request is a pinned request.
    pub adaptive: Option<bool>,
    /// Per-request draft-source override (None = server config):
    /// `"model"` pins the classic two-model draft, `"extrap"` the
    /// draft-free continuation, `"adaptive"` the online-learned head.
    /// SD jobs group by draft kind, so mixed traffic batches cleanly.
    /// Overriding the kind routes the job to the *static*-γ path: the
    /// server's long-lived γ controller is tuned per-source, so an
    /// explicit `"adaptive": true` combined with a different kind is
    /// rejected rather than cross-contaminating its c/α̂ estimates.
    pub draft: Option<DraftKind>,
    /// Traffic-segment tag for acceptance monitoring (paper §7).
    pub dataset: Option<String>,
}

impl ForecastRequest {
    /// Parse and validate a request from its JSON body.
    pub fn from_json(j: &Json) -> Result<ForecastRequest> {
        let history: Vec<f32> = j
            .get("history")
            .and_then(Json::as_arr)
            .context("'history' array required")?
            .iter()
            .map(|v| v.as_f64().map(|x| x as f32).context("history values must be numbers"))
            .collect::<Result<_>>()?;
        if history.is_empty() {
            bail!("'history' must be non-empty");
        }
        let horizon = j.get("horizon").and_then(Json::as_usize).context("'horizon' required")?;
        if horizon == 0 || horizon > 1024 {
            bail!("'horizon' must be in [1, 1024] patches");
        }
        let mode = match j.get("mode").and_then(Json::as_str) {
            None | Some("sd") => Mode::Sd,
            Some("baseline") => Mode::Baseline,
            Some("draft") => Mode::DraftOnly,
            Some(other) => bail!("unknown mode '{other}'"),
        };
        let gamma = j.get("gamma").and_then(Json::as_usize);
        if let Some(g) = gamma {
            if g == 0 || g > 64 {
                bail!("'gamma' must be in [1, 64]");
            }
        }
        let sigma = j.get("sigma").and_then(Json::as_f64);
        if let Some(s) = sigma {
            if !(s > 0.0 && s < 100.0) {
                bail!("'sigma' must be in (0, 100)");
            }
        }
        let draft = match j.get("draft").and_then(Json::as_str) {
            None => None,
            Some(s) => Some(
                DraftKind::parse(s)
                    .with_context(|| format!("unknown draft kind '{s}' (model|extrap|adaptive)"))?,
            ),
        };
        Ok(ForecastRequest {
            history,
            horizon,
            mode,
            gamma,
            sigma,
            cache: j.get("cache").and_then(Json::as_bool),
            adaptive: j.get("adaptive").and_then(Json::as_bool),
            draft,
            dataset: j.get("dataset").and_then(Json::as_str).map(String::from),
        })
    }
}

/// One `/forecast` response body.
#[derive(Clone, Debug, Default)]
pub struct ForecastResponse {
    /// Forecast values, flat `[horizon * patch]`.
    pub forecast: Vec<f32>,
    /// Mode actually served (`"sd"` / `"baseline"` / `"draft"`).
    pub mode: String,
    /// Draft source that produced the proposals (`"model"` / `"extrap"`
    /// / `"adaptive"`; empty for the AR modes, which draft nothing).
    pub draft: String,
    /// End-to-end request latency in milliseconds.
    pub latency_ms: f64,
    /// Mean acceptance probability of this decode (NaN for AR modes).
    pub alpha_hat: f64,
    /// Mean emitted patches per round (NaN for AR modes).
    pub mean_block_len: f64,
    /// Speculative rounds (or AR steps) executed.
    pub rounds: usize,
    /// Draft forward passes consumed.
    pub draft_calls: usize,
    /// Target forward passes consumed.
    pub target_calls: usize,
}

impl ForecastResponse {
    /// Serialize to the wire JSON (non-finite stats become `null`).
    pub fn to_json(&self) -> Json {
        fn num(v: f64) -> Json {
            if v.is_finite() {
                Json::Num(v)
            } else {
                Json::Null
            }
        }
        Json::obj(vec![
            ("forecast", Json::arr_f32(&self.forecast)),
            ("mode", Json::from(self.mode.as_str())),
            ("draft", Json::from(self.draft.as_str())),
            ("latency_ms", num(self.latency_ms)),
            ("alpha_hat", num(self.alpha_hat)),
            ("mean_block_len", num(self.mean_block_len)),
            ("rounds", Json::from(self.rounds)),
            ("draft_calls", Json::from(self.draft_calls)),
            ("target_calls", Json::from(self.target_calls)),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_minimal_request() {
        let j = Json::parse(r#"{"history": [1.0, 2.0], "horizon": 4}"#).unwrap();
        let r = ForecastRequest::from_json(&j).unwrap();
        assert_eq!(r.history, vec![1.0, 2.0]);
        assert_eq!(r.horizon, 4);
        assert_eq!(r.mode, Mode::Sd);
        assert!(r.gamma.is_none());
    }

    #[test]
    fn parses_full_request() {
        let j = Json::parse(
            r#"{"history": [0.5], "horizon": 2, "mode": "baseline", "gamma": 5,
                "sigma": 0.7, "dataset": "etth1"}"#,
        )
        .unwrap();
        let r = ForecastRequest::from_json(&j).unwrap();
        assert_eq!(r.mode, Mode::Baseline);
        assert_eq!(r.gamma, Some(5));
        assert_eq!(r.dataset.as_deref(), Some("etth1"));
        assert_eq!(r.adaptive, None);
        assert_eq!(r.draft, None);
    }

    #[test]
    fn parses_draft_override() {
        let j = Json::parse(r#"{"history": [0.5], "horizon": 2, "draft": "extrap"}"#).unwrap();
        assert_eq!(ForecastRequest::from_json(&j).unwrap().draft, Some(DraftKind::Extrap));
        let j = Json::parse(r#"{"history": [0.5], "horizon": 2, "draft": "adaptive"}"#).unwrap();
        assert_eq!(ForecastRequest::from_json(&j).unwrap().draft, Some(DraftKind::Adaptive));
        let j = Json::parse(r#"{"history": [0.5], "horizon": 2, "draft": "warp"}"#).unwrap();
        assert!(ForecastRequest::from_json(&j).is_err());
    }

    #[test]
    fn parses_adaptive_override() {
        let j = Json::parse(r#"{"history": [0.5], "horizon": 2, "adaptive": true}"#).unwrap();
        assert_eq!(ForecastRequest::from_json(&j).unwrap().adaptive, Some(true));
        let j = Json::parse(r#"{"history": [0.5], "horizon": 2, "adaptive": false}"#).unwrap();
        assert_eq!(ForecastRequest::from_json(&j).unwrap().adaptive, Some(false));
    }

    #[test]
    fn rejects_bad_requests() {
        for bad in [
            r#"{"horizon": 4}"#,
            r#"{"history": [], "horizon": 4}"#,
            r#"{"history": [1], "horizon": 0}"#,
            r#"{"history": [1], "horizon": 4, "mode": "warp"}"#,
            r#"{"history": [1], "horizon": 4, "gamma": 0}"#,
            r#"{"history": [1], "horizon": 4, "sigma": -1}"#,
            r#"{"history": ["x"], "horizon": 4}"#,
        ] {
            let j = Json::parse(bad).unwrap();
            assert!(ForecastRequest::from_json(&j).is_err(), "should reject {bad}");
        }
    }

    #[test]
    fn response_roundtrips() {
        let resp = ForecastResponse {
            forecast: vec![1.0, 2.0],
            mode: "sd".into(),
            draft: "model".into(),
            latency_ms: 3.5,
            alpha_hat: 0.97,
            mean_block_len: 3.4,
            rounds: 2,
            draft_calls: 6,
            target_calls: 2,
        };
        let j = resp.to_json();
        let parsed = Json::parse(&j.to_string()).unwrap();
        assert_eq!(parsed.get("mode").unwrap().as_str(), Some("sd"));
        assert_eq!(parsed.get("draft").unwrap().as_str(), Some("model"));
        assert_eq!(parsed.get("rounds").unwrap().as_usize(), Some(2));
        assert_eq!(parsed.get("forecast").unwrap().as_arr().unwrap().len(), 2);
    }

    #[test]
    fn nan_stats_serialize_as_null() {
        let resp = ForecastResponse { alpha_hat: f64::NAN, ..Default::default() };
        let j = resp.to_json();
        assert_eq!(j.get("alpha_hat"), Some(&Json::Null));
    }
}
