//! Dynamic batcher + engine thread: the serving coordinator's core loop.
//!
//! HTTP workers enqueue jobs; a single engine thread (which owns all PJRT
//! state — the xla crate's client is not Send) drains the queue with a
//! size-or-deadline policy (max_batch / max_wait_ms), groups compatible
//! speculative jobs into one lockstep batched decode, and replies through
//! per-job channels. This is the continuous-batching shape vLLM-style
//! servers use, specialized to fixed-shape PJRT executables.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use super::protocol::{ForecastRequest, ForecastResponse, Mode};
use crate::config::ServeConfig;
use crate::forecast::ar_decode_with;
use crate::metrics::{AcceptanceMonitor, Metrics};
use crate::models::{Backend, CacheMode, NativeBackend, XlaBackend};
use crate::runtime::{Engine, Manifest};
use crate::specdec::{
    make_batch_source, sd_generate_stream_from, DecodeStats, DraftKind, GammaController,
    SpecConfig,
};

/// One queued forecast request plus its reply channel.
pub struct Job {
    /// The parsed request.
    pub req: ForecastRequest,
    /// Enqueue time (request latency is measured from here).
    pub enqueued: Instant,
    /// Channel the engine thread answers on.
    pub reply: mpsc::SyncSender<Result<ForecastResponse, String>>,
}

/// Handle held by the HTTP side.
#[derive(Clone)]
pub struct BatcherHandle {
    tx: mpsc::Sender<Job>,
    /// Shared metrics registry (also rendered at `/metrics`).
    pub metrics: Arc<Metrics>,
    /// Windowed acceptance monitor (alerting; paper §7).
    pub monitor: Arc<AcceptanceMonitor>,
    /// The server's long-lived adaptive γ controller, present when
    /// `ServeConfig::adaptive` is on. Its recommendation seeds each
    /// adaptive decode group (so jobs regroup as γ drifts) and every
    /// finished group's rounds are fed back. Exposed read-only via
    /// `/stats`.
    pub controller: Option<Arc<Mutex<GammaController>>>,
    /// The server's default draft-source kind (per-request `"draft"`
    /// overrides route jobs to other kinds; `/stats` reports per-kind
    /// aggregates).
    pub draft: DraftKind,
}

impl BatcherHandle {
    /// Synchronous request-response (the HTTP worker blocks here).
    pub fn forecast(&self, req: ForecastRequest) -> Result<ForecastResponse, String> {
        let (tx, rx) = mpsc::sync_channel(1);
        let job = Job { req, enqueued: Instant::now(), reply: tx };
        self.tx.send(job).map_err(|_| "engine thread gone".to_string())?;
        rx.recv_timeout(Duration::from_secs(120))
            .map_err(|_| "engine timeout".to_string())?
    }
}

/// Spawn the engine thread; blocks until backends are loaded (or fails).
pub fn start_engine(
    cfg: ServeConfig,
    metrics: Arc<Metrics>,
    monitor: Arc<AcceptanceMonitor>,
    stop: Arc<AtomicBool>,
) -> Result<(BatcherHandle, std::thread::JoinHandle<()>)> {
    let (tx, rx) = mpsc::channel::<Job>();
    let (ready_tx, ready_rx) = mpsc::sync_channel::<Result<String, String>>(1);
    let controller = if cfg.adaptive {
        let mut ctrl = GammaController::new(cfg.adaptive_cfg, cfg.gamma, cfg.sigma);
        // Tag the telemetry with the server's default source: the c this
        // controller measures (and the γ it recommends) is per-source.
        ctrl.set_draft_kind(cfg.draft.kind.as_str());
        Some(Arc::new(Mutex::new(ctrl)))
    } else {
        None
    };
    let m2 = metrics.clone();
    let mon2 = monitor.clone();
    let ctrl2 = controller.clone();
    let draft_kind = cfg.draft.kind;
    let handle = std::thread::Builder::new()
        .name("stride-engine".into())
        .spawn(move || engine_main(cfg, rx, ready_tx, m2, mon2, ctrl2, stop))
        .context("spawning engine thread")?;
    match ready_rx.recv().context("engine thread died during startup")? {
        Ok(desc) => log::info!("engine ready: {desc}"),
        Err(e) => anyhow::bail!("engine startup failed: {e}"),
    }
    Ok((BatcherHandle { tx, metrics, monitor, controller, draft: draft_kind }, handle))
}

fn load_backends(cfg: &ServeConfig) -> Result<(Box<dyn Backend>, Box<dyn Backend>, Manifest)> {
    let manifest = Manifest::load(&cfg.artifacts)?;
    match cfg.backend.as_str() {
        "native" => {
            let (t, d) = NativeBackend::pair_from_manifest(&manifest)?;
            Ok((Box::new(t), Box::new(d), manifest))
        }
        "xla" => {
            let mut engine = Engine::cpu()?;
            let t = XlaBackend::load(&mut engine, &manifest, "target", &cfg.kernel)?;
            let d = XlaBackend::load(&mut engine, &manifest, "draft", &cfg.kernel)?;
            Ok((Box::new(t), Box::new(d), manifest))
        }
        other => anyhow::bail!("unknown backend {other}"),
    }
}

fn engine_main(
    cfg: ServeConfig,
    rx: mpsc::Receiver<Job>,
    ready: mpsc::SyncSender<Result<String, String>>,
    metrics: Arc<Metrics>,
    monitor: Arc<AcceptanceMonitor>,
    controller: Option<Arc<Mutex<GammaController>>>,
    stop: Arc<AtomicBool>,
) {
    let (target, draft, manifest) = match load_backends(&cfg) {
        Ok(v) => {
            let _ = ready.send(Ok(format!(
                "backend={} target={} draft={} patch={} n_ctx={}",
                cfg.backend,
                v.0.name(),
                v.1.name(),
                v.2.patch,
                v.2.n_ctx
            )));
            v
        }
        Err(e) => {
            let _ = ready.send(Err(format!("{e:#}")));
            return;
        }
    };

    // Spin up the kernel layer's shared compute pool before the first
    // request: prefill matmuls and the batched verify fan over it. A
    // `threads` setting fixes the size; 0 leaves the STRIDE_THREADS /
    // auto default. (First initialization wins process-wide.)
    let pool_size = if cfg.threads > 0 {
        crate::util::threadpool::init_global_pool(cfg.threads)
    } else {
        crate::util::threadpool::global_pool().size()
    };
    log::info!("kernel compute pool: {pool_size} threads");

    // Warm the executables so the first request doesn't pay compile cost.
    let p = manifest.patch;
    let warm = vec![0.0f32; manifest.n_ctx * p];
    let _ = target.forward(&warm, manifest.n_ctx);
    let _ = draft.forward(&warm, manifest.n_ctx);

    let max_wait = Duration::from_millis(cfg.max_wait_ms);
    // Learned draft-source state carried across decode groups (engine
    // thread only, no locking): learning kinds export their parameter
    // snapshot after each group and the next group's fresh sources are
    // seeded with it — online adaptation survives across requests
    // instead of cold-starting per batch.
    let mut draft_heads: BTreeMap<DraftKind, Vec<f32>> = BTreeMap::new();
    loop {
        // Block for the first job (with timeout so `stop` is honored).
        let first = match rx.recv_timeout(Duration::from_millis(50)) {
            Ok(j) => j,
            Err(mpsc::RecvTimeoutError::Timeout) => {
                if stop.load(Ordering::Relaxed) {
                    return;
                }
                continue;
            }
            Err(mpsc::RecvTimeoutError::Disconnected) => return,
        };
        // Drain until the batch is full or the deadline passes.
        let mut jobs = vec![first];
        let deadline = jobs[0].enqueued + max_wait;
        while jobs.len() < cfg.max_batch {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            match rx.recv_timeout(deadline - now) {
                Ok(j) => jobs.push(j),
                Err(_) => break,
            }
        }
        metrics.inc("batches", 1);
        metrics.inc("batched_jobs", jobs.len() as u64);
        process_batch(
            &cfg,
            &manifest,
            target.as_ref(),
            draft.as_ref(),
            jobs,
            &metrics,
            &monitor,
            controller.as_deref(),
            &mut draft_heads,
        );
    }
}

/// Validate + normalize one request into (history, n_hist, horizon).
fn prep(req: &ForecastRequest, manifest: &Manifest, gamma: usize) -> Result<(Vec<f32>, usize, usize), String> {
    let p = manifest.patch;
    if req.history.len() % p != 0 {
        return Err(format!(
            "history length {} not a multiple of patch {p}",
            req.history.len()
        ));
    }
    let n_hist = req.history.len() / p;
    // Keep at most the context the models can see during a round.
    let keep = manifest.n_ctx.saturating_sub(gamma + 1).max(1);
    let hist = if n_hist > keep {
        req.history[(n_hist - keep) * p..].to_vec()
    } else {
        req.history.clone()
    };
    let n = hist.len() / p;
    Ok((hist, n, req.horizon))
}

#[allow(clippy::too_many_arguments)]
fn process_batch(
    cfg: &ServeConfig,
    manifest: &Manifest,
    target: &dyn Backend,
    draft: &dyn Backend,
    jobs: Vec<Job>,
    metrics: &Metrics,
    monitor: &AcceptanceMonitor,
    controller: Option<&Mutex<GammaController>>,
    draft_heads: &mut BTreeMap<DraftKind, Vec<f32>>,
) {
    // Partition: SD jobs grouped by (gamma, sigma-bits, cache, adaptive,
    // draft-kind) so overrides batch together — a decode group shares one
    // session pool, one draft source, one cost model, and one adaptation
    // mode; baseline/draft jobs run individually. Adaptive jobs take the
    // live controller's current recommendation as their γ key, so they
    // *regroup automatically* as the controller drifts — the γ in the key
    // is also the γ that seeds the group's per-sequence controllers.
    let mut sd_groups: BTreeMap<(usize, u64, bool, bool, DraftKind), Vec<Job>> = BTreeMap::new();
    let mut singles: Vec<Job> = Vec::new();
    let base_spec = cfg.spec_config();

    for job in jobs {
        metrics.requests_total.fetch_add(1, Ordering::Relaxed);
        match job.req.mode {
            Mode::Sd if !cfg.baseline => {
                // Asking for adaptation on a server that runs without a
                // controller is a request we cannot honor — reject it
                // rather than silently serving static gamma.
                if job.req.adaptive == Some(true) && controller.is_none() {
                    metrics.errors_total.fetch_add(1, Ordering::Relaxed);
                    let _ = job.reply.send(Err(
                        "adaptive speculation is not enabled on this server \
                         (start it with --adaptive)"
                            .to_string(),
                    ));
                    continue;
                }
                let draft_kind = job.req.draft.unwrap_or(cfg.draft.kind);
                // The long-lived controller's α̂/c telemetry is
                // per-source: rounds from a different draft kind would
                // contaminate the estimates the default kind's γ is
                // tuned from (an extrap group's c ≈ 0 would peg γ at
                // max for everyone). Jobs overriding the draft kind
                // cannot ride the controller — reject an explicit ask,
                // and run implicitly-adaptive overrides on the static
                // path.
                if job.req.adaptive == Some(true) && draft_kind != cfg.draft.kind {
                    metrics.errors_total.fetch_add(1, Ordering::Relaxed);
                    let _ = job.reply.send(Err(format!(
                        "adaptive speculation rides the server's long-lived \
                         controller, which is tuned for draft '{}'; drop the \
                         per-request draft override or the adaptive flag",
                        cfg.draft.kind.as_str()
                    )));
                    continue;
                }
                // An explicit per-request gamma always pins the job to
                // the static path: a pinned request is a pinned request.
                let adaptive = controller.is_some()
                    && job.req.adaptive.unwrap_or(cfg.adaptive)
                    && job.req.gamma.is_none()
                    && draft_kind == cfg.draft.kind;
                let gamma = if adaptive {
                    let ctrl = controller.unwrap().lock().unwrap();
                    ctrl.gamma_for(manifest.n_ctx)
                } else {
                    job.req.gamma.unwrap_or(cfg.gamma)
                };
                let sigma = job.req.sigma.unwrap_or(cfg.sigma);
                let cache = job.req.cache.unwrap_or(cfg.cache);
                sd_groups
                    .entry((gamma, sigma.to_bits(), cache, adaptive, draft_kind))
                    .or_default()
                    .push(job);
            }
            _ => singles.push(job),
        }
    }

    // Per-group decode seed: reusing one RNG stream across batches would
    // correlate accept/reject coins between requests.
    static DECODE_SEQ: std::sync::atomic::AtomicU64 = std::sync::atomic::AtomicU64::new(0);
    for ((gamma, sigma_bits, cache, adaptive, kind), group) in sd_groups {
        let sigma = f64::from_bits(sigma_bits);
        let mut spec = base_spec;
        spec.gamma = gamma;
        spec.policy.sigma = sigma;
        spec.cache = if cache { CacheMode::On } else { CacheMode::Off };
        spec.draft.kind = kind;
        spec.adaptive = if adaptive { Some(cfg.adaptive_cfg) } else { None };
        spec.seed = spec
            .seed
            .wrapping_add(DECODE_SEQ.fetch_add(1, Ordering::Relaxed))
            .wrapping_mul(0x9E37_79B9_7F4A_7C15);
        let ctrl = if adaptive { controller } else { None };
        run_sd_group(manifest, target, draft, group, &spec, metrics, monitor, ctrl, draft_heads);
    }
    for job in singles {
        run_single(cfg, manifest, target, draft, job, metrics);
    }
}

#[allow(clippy::too_many_arguments)]
fn run_sd_group(
    manifest: &Manifest,
    target: &dyn Backend,
    draft: &dyn Backend,
    group: Vec<Job>,
    spec: &SpecConfig,
    metrics: &Metrics,
    monitor: &AcceptanceMonitor,
    controller: Option<&Mutex<GammaController>>,
    draft_heads: &mut BTreeMap<DraftKind, Vec<f32>>,
) {
    // Validate all; drop invalid with error replies.
    let mut ok_jobs = Vec::new();
    let mut preps: Vec<(Vec<f32>, usize, usize)> = Vec::new();
    for job in group {
        match prep(&job.req, manifest, spec.gamma) {
            Ok(p) => {
                preps.push(p);
                ok_jobs.push(job);
            }
            Err(e) => {
                metrics.errors_total.fetch_add(1, Ordering::Relaxed);
                let _ = job.reply.send(Err(e));
            }
        }
    }
    if ok_jobs.is_empty() {
        return;
    }
    let tasks: Vec<(&[f32], usize, usize)> =
        preps.iter().map(|(h, n, hz)| (h.as_slice(), *n, *hz)).collect();
    // Build the group's draft source explicitly so learned state can be
    // threaded across groups: seed fresh sources with the last exported
    // head of this kind, export back after the decode.
    let mut source = match make_batch_source(&spec.draft, draft) {
        Ok(s) => s,
        Err(e) => {
            for job in ok_jobs {
                metrics.errors_total.fetch_add(1, Ordering::Relaxed);
                let _ = job.reply.send(Err(format!("draft source failed: {e:#}")));
            }
            return;
        }
    };
    if let Some(h) = draft_heads.get(&spec.draft.kind) {
        if let Err(e) = source.import_head(h) {
            log::warn!("stale draft head discarded: {e:#}");
            draft_heads.remove(&spec.draft.kind);
        }
    }
    let t0 = Instant::now();
    match sd_generate_stream_from(target, source.as_mut(), &tasks, usize::MAX, spec) {
        Ok(outs) => {
            if let Some(h) = source.export_head() {
                draft_heads.insert(spec.draft.kind, h);
            }
            let batch_wall = t0.elapsed();
            // Feed the finished group back into the server's long-lived
            // controller: every round (including rejected ones) updates
            // α̂/c, and the next batch's adaptive jobs will key on the
            // possibly-retuned γ. Gauges expose the live state.
            if let Some(ctrl) = controller {
                let mut c = ctrl.lock().unwrap();
                for out in &outs {
                    for r in &out.rounds {
                        c.observe_round(r);
                    }
                }
                let s = c.state();
                drop(c);
                metrics.set_gauge("controller_gamma", s.gamma as f64);
                metrics.set_gauge("controller_alpha_hat", s.alpha_hat);
                metrics.set_gauge("controller_c", s.c);
                metrics.set_gauge("controller_rounds", s.rounds as f64);
                metrics.set_gauge("controller_gamma_changes", s.gamma_changes as f64);
            }
            // Per-draft-source serving aggregates: which source kinds are
            // live, their acceptance α̂, their measured cost ratio c, and
            // (for learning sources) how many online updates they apply.
            // α̂/c fold as EWMAs so the gauges track traffic rather than
            // echoing the last group; decode/update counts are monotone.
            let kind = spec.draft.kind.as_str();
            let mut agg = DecodeStats::default();
            for out in &outs {
                agg.merge(&out.stats);
            }
            metrics.inc(&format!("draft_{kind}_decodes"), outs.len() as u64);
            metrics.inc(&format!("draft_{kind}_updates"), agg.draft_updates as u64);
            metrics.ewma_gauge(&format!("draft_{kind}_alpha_hat"), agg.alpha_hat(), 0.8);
            metrics.ewma_gauge(&format!("draft_{kind}_c"), agg.cost_ratio(), 0.8);
            for (job, out) in ok_jobs.into_iter().zip(outs) {
                let latency = job.enqueued.elapsed();
                metrics.observe("request_latency", latency);
                metrics.observe("decode_latency", batch_wall);
                metrics.patches_total.fetch_add(out.patches.len() as u64 / manifest.patch as u64, Ordering::Relaxed);
                let alpha = out.stats.alpha_hat();
                if alpha.is_finite() {
                    monitor.record(alpha);
                }
                let resp = ForecastResponse {
                    forecast: out.patches,
                    mode: "sd".into(),
                    draft: spec.draft.kind.as_str().into(),
                    latency_ms: latency.as_secs_f64() * 1e3,
                    alpha_hat: alpha,
                    mean_block_len: out.stats.mean_block_len(),
                    rounds: out.stats.rounds,
                    draft_calls: out.stats.draft_calls,
                    target_calls: out.stats.target_calls,
                };
                let _ = job.reply.send(Ok(resp));
            }
        }
        Err(e) => {
            for job in ok_jobs {
                metrics.errors_total.fetch_add(1, Ordering::Relaxed);
                let _ = job.reply.send(Err(format!("decode failed: {e:#}")));
            }
        }
    }
}

fn run_single(
    cfg: &ServeConfig,
    manifest: &Manifest,
    target: &dyn Backend,
    draft: &dyn Backend,
    job: Job,
    metrics: &Metrics,
) {
    let model: &dyn Backend = match job.req.mode {
        Mode::DraftOnly => draft,
        _ => target,
    };
    let cache = if job.req.cache.unwrap_or(cfg.cache) { CacheMode::On } else { CacheMode::Off };
    let result = (|| -> Result<ForecastResponse, String> {
        let (hist, n_hist, horizon) = prep(&job.req, manifest, 1)?;
        let (pred, _wall, calls) =
            ar_decode_with(model, &hist, n_hist, horizon, cache).map_err(|e| format!("{e:#}"))?;
        let latency = job.enqueued.elapsed();
        metrics.observe("request_latency", latency);
        metrics
            .patches_total
            .fetch_add(horizon as u64, Ordering::Relaxed);
        Ok(ForecastResponse {
            forecast: pred,
            mode: if job.req.mode == Mode::DraftOnly { "draft" } else { "baseline" }.into(),
            // AR modes draft nothing; the field names the proposal source
            // of SD decodes only.
            draft: String::new(),
            latency_ms: latency.as_secs_f64() * 1e3,
            alpha_hat: f64::NAN,
            mean_block_len: f64::NAN,
            rounds: horizon,
            draft_calls: if job.req.mode == Mode::DraftOnly { calls } else { 0 },
            target_calls: if job.req.mode == Mode::DraftOnly { 0 } else { calls },
        })
    })();
    if result.is_err() {
        metrics.errors_total.fetch_add(1, Ordering::Relaxed);
    }
    let _ = job.reply.send(result);
}
