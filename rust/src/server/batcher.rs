//! Admission + execution glue of the serving tier.
//!
//! HTTP workers call [`BatcherHandle::forecast`]: the request is keyed by
//! its decode-compatibility group, stamped with priority/deadline, and
//! admitted into the bounded [`AdmissionQueue`] (sched subsystem). Engine
//! replicas pull EDF-ordered batches from the queue and run them through
//! [`execute_batch`]: one lockstep speculative decode per k = 1 SD group
//! (per-request seeds through [`sd_generate_stream_seeded`], so responses
//! are replica- and batching-invariant), per-job tree decodes for k > 1
//! groups (the batch axis is spent on candidate branches — see
//! [`sd_generate_tree_from`]), individual AR decodes for the baseline
//! modes. Replies travel per-job channels, typed as
//! [`ServeError`] so the HTTP layer can map shed/expired/invalid/internal
//! to distinct statuses.
//!
//! The pre-scheduler single-FIFO engine loop is gone; `start_engine`
//! now stands up the scheduler (queue + replica pool) and returns a
//! handle with the same surface the HTTP router always used.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::Result;

use super::protocol::{ForecastRequest, ForecastResponse, Mode, ServeError};
use super::sched::{
    start_pool, AdmissionQueue, GroupKey, ModelShape, QueuedJob, ReplicaBuilder, ReplicaStacks,
    SchedShared,
};
use crate::config::ServeConfig;
use crate::forecast::ar_decode_with;
use crate::metrics::{AcceptanceMonitor, Metrics};
use crate::models::{Backend, CacheMode, NativeBackend, XlaBackend};
use crate::runtime::{Engine, Manifest};
use crate::specdec::{
    make_batch_source, make_source, sd_generate_stream_seeded, sd_generate_tree_from,
    DecodeStats, DraftKind, GammaController, SpecConfig,
};

/// One queued forecast request plus its reply channel.
pub struct Job {
    /// The parsed request.
    pub req: ForecastRequest,
    /// Enqueue time (request latency and deadlines are measured from
    /// here).
    pub enqueued: Instant,
    /// Channel the executing replica (or the queue, for shed/expired
    /// jobs) answers on.
    pub reply: mpsc::SyncSender<Result<ForecastResponse, ServeError>>,
}

/// Handle held by the HTTP side.
#[derive(Clone)]
pub struct BatcherHandle {
    cfg: Arc<ServeConfig>,
    shape: ModelShape,
    queue: Arc<AdmissionQueue>,
    /// Shared metrics registry (also rendered at `/metrics`).
    pub metrics: Arc<Metrics>,
    /// Windowed acceptance monitor (alerting; paper §7).
    pub monitor: Arc<AcceptanceMonitor>,
    /// The server's long-lived adaptive γ controller, present when
    /// `ServeConfig::adaptive` is on. Its recommendation seeds each
    /// adaptive decode group (so jobs regroup as γ drifts) and every
    /// finished group's rounds are fed back — from whichever replica ran
    /// them. Exposed read-only via `/stats`.
    pub controller: Option<Arc<Mutex<GammaController>>>,
    /// The server's default draft-source kind (per-request `"draft"`
    /// overrides route jobs to other kinds; `/stats` reports per-kind
    /// aggregates).
    pub draft: DraftKind,
}

impl BatcherHandle {
    /// Synchronous request-response (the HTTP worker blocks here).
    /// Admission failures (shed / invalid) return immediately; admitted
    /// jobs wait for their replica's reply.
    pub fn forecast(&self, req: ForecastRequest) -> Result<ForecastResponse, ServeError> {
        self.metrics.requests_total.fetch_add(1, Ordering::Relaxed);
        let mut req = req;
        // Seed discipline: a request that pins a seed is exactly
        // reproducible (bit-identical to `sd_generate_from` at that
        // seed, any replica count). Unseeded requests get a fresh
        // decode seed here — without this, all unseeded traffic would
        // share one RNG stream and `"sampled"` clients repeating a
        // request would receive N copies of one draw instead of N
        // samples. The assigned seed is echoed in the response, so any
        // served forecast can be replayed afterwards.
        if req.seed.is_none() {
            static REQ_SEQ: std::sync::atomic::AtomicU64 = std::sync::atomic::AtomicU64::new(0);
            req.seed = Some(
                self.cfg
                    .seed
                    .wrapping_add(REQ_SEQ.fetch_add(1, Ordering::Relaxed))
                    .wrapping_mul(0x9E37_79B9_7F4A_7C15),
            );
        }
        let key = self.group_key(&req)?;
        let priority = req.priority;
        let deadline_ms = req.deadline_ms.or(if self.cfg.default_deadline_ms > 0 {
            Some(self.cfg.default_deadline_ms)
        } else {
            None
        });
        let (tx, rx) = mpsc::sync_channel(1);
        let job = Job { req, enqueued: Instant::now(), reply: tx };
        self.queue.admit(job, priority, deadline_ms, key)?;
        match rx.recv_timeout(Duration::from_secs(120)) {
            Ok(r) => r,
            Err(_) => Err(ServeError::Internal("engine timeout".into())),
        }
    }

    /// Compute the request's decode-compatibility group (and reject the
    /// combinations the server cannot honor, before they cost a queue
    /// slot).
    fn group_key(&self, req: &ForecastRequest) -> Result<GroupKey, ServeError> {
        let cfg = &self.cfg;
        match req.mode {
            Mode::Sd if !cfg.baseline => {
                // Asking for adaptation on a server that runs without a
                // controller is a request we cannot honor — reject it
                // rather than silently serving static gamma.
                if req.adaptive == Some(true) && self.controller.is_none() {
                    self.metrics.errors_total.fetch_add(1, Ordering::Relaxed);
                    return Err(ServeError::Invalid(
                        "adaptive speculation is not enabled on this server \
                         (start it with --adaptive)"
                            .to_string(),
                    ));
                }
                let kind = req.draft.unwrap_or(cfg.draft.kind);
                // The long-lived controller's α̂/c telemetry is
                // per-source: rounds from a different draft kind would
                // contaminate the estimates the default kind's γ is
                // tuned from. Jobs overriding the draft kind cannot ride
                // the controller.
                if req.adaptive == Some(true) && kind != cfg.draft.kind {
                    self.metrics.errors_total.fetch_add(1, Ordering::Relaxed);
                    return Err(ServeError::Invalid(format!(
                        "adaptive speculation rides the server's long-lived \
                         controller, which is tuned for draft '{}'; drop the \
                         per-request draft override or the adaptive flag",
                        cfg.draft.kind.as_str()
                    )));
                }
                // An explicit per-request gamma (or k) always pins the
                // job to the static path: a pinned request is a pinned
                // request.
                let adaptive = self.controller.is_some()
                    && req.adaptive.unwrap_or(cfg.adaptive)
                    && req.gamma.is_none()
                    && req.k.is_none()
                    && kind == cfg.draft.kind;
                let (gamma, k) = if adaptive {
                    let ctrl = self.controller.as_ref().unwrap().lock().unwrap();
                    (ctrl.gamma_for(self.shape.n_ctx), ctrl.k())
                } else {
                    (req.gamma.unwrap_or(cfg.gamma), req.k.unwrap_or(cfg.k))
                };
                // Lossless decoding is proven only for k = 1 (the
                // equivalence wall); a per-request k override cannot
                // widen a lossless server's tree.
                if k > 1 && cfg.lossless {
                    self.metrics.errors_total.fetch_add(1, Ordering::Relaxed);
                    return Err(ServeError::Invalid(
                        "tree speculation (k > 1) requires the practical \
                         variant; this server runs lossless decoding"
                            .to_string(),
                    ));
                }
                let sigma = req.sigma.unwrap_or(cfg.sigma);
                let cache = req.cache.unwrap_or(cfg.cache);
                Ok(GroupKey::Sd { gamma, k, sigma_bits: sigma.to_bits(), cache, adaptive, kind })
            }
            _ => Ok(GroupKey::Single),
        }
    }

    /// Jobs currently waiting in the admission queue.
    pub fn queue_depth(&self) -> usize {
        self.queue.depth()
    }

    /// The admission queue's hard cap.
    pub fn queue_cap(&self) -> usize {
        self.queue.cap()
    }

    /// Readiness: false while the admission queue is saturated (the
    /// `/healthz` 503 signal for external load balancers).
    pub fn ready(&self) -> bool {
        !self.queue.saturated()
    }

    /// The scheduler's dispatch policy name (`"edf"` / `"fifo"`).
    pub fn sched_policy(&self) -> &'static str {
        self.queue.policy().as_str()
    }

    /// Engine replicas serving this queue.
    pub fn replicas(&self) -> usize {
        self.cfg.replicas
    }

    /// Stop the scheduler: refuse new admissions, fail queued jobs, and
    /// let the replica threads drain out.
    pub fn shutdown(&self) {
        self.queue.shutdown();
    }
}

/// Spawn the scheduler (admission queue + replica pool) from the
/// artifacts manifest; blocks until every replica's backends are loaded
/// (or fails).
pub fn start_engine(
    cfg: ServeConfig,
    metrics: Arc<Metrics>,
    monitor: Arc<AcceptanceMonitor>,
    stop: Arc<AtomicBool>,
) -> Result<(BatcherHandle, Vec<std::thread::JoinHandle<()>>)> {
    let (shape, builder) = builder_from_artifacts(&cfg)?;
    start_engine_with_builder(cfg, shape, builder, metrics, monitor, stop)
}

/// [`start_engine`] with an injected replica builder — the entry point
/// that lets tests and benches run the complete serving stack (HTTP,
/// admission, EDF dispatch, replica pool) over synthetic in-memory
/// models, no artifacts directory required.
pub fn start_engine_with_builder(
    cfg: ServeConfig,
    shape: ModelShape,
    builder: ReplicaBuilder,
    metrics: Arc<Metrics>,
    monitor: Arc<AcceptanceMonitor>,
    stop: Arc<AtomicBool>,
) -> Result<(BatcherHandle, Vec<std::thread::JoinHandle<()>>)> {
    let controller = if cfg.adaptive {
        let mut ctrl = GammaController::new(cfg.adaptive_cfg, cfg.gamma, cfg.sigma);
        // Tag the telemetry with the server's default source: the c this
        // controller measures (and the γ it recommends) is per-source.
        ctrl.set_draft_kind(cfg.draft.kind.as_str());
        Some(Arc::new(Mutex::new(ctrl)))
    } else {
        None
    };
    let draft_kind = cfg.draft.kind;
    let cfg = Arc::new(cfg);
    let queue = Arc::new(AdmissionQueue::new(
        cfg.queue_cap,
        cfg.sched,
        cfg.retry_after_ms,
        metrics.clone(),
        Arc::clone(&stop),
    ));
    let shared = Arc::new(SchedShared {
        metrics: metrics.clone(),
        monitor: monitor.clone(),
        controller: controller.clone(),
        draft_heads: Mutex::new(BTreeMap::new()),
    });
    let handles = start_pool(
        Arc::clone(&cfg),
        shape,
        builder,
        Arc::clone(&queue),
        Arc::clone(&shared),
        stop,
    )?;
    Ok((
        BatcherHandle { cfg, shape, queue, metrics, monitor, controller, draft: draft_kind },
        handles,
    ))
}

/// Resolve the manifest into (shape, replica builder). The native
/// backend loads each weight blob **once** here; every replica's stack
/// is a [`NativeBackend::replicate`] over that single `Arc` storage
/// (packing copies pointers, not floats). The xla backend constructs
/// its PJRT state on the replica thread itself (the client is not
/// `Send`) and is limited to one replica by `ServeConfig::validate`.
fn builder_from_artifacts(cfg: &ServeConfig) -> Result<(ModelShape, ReplicaBuilder)> {
    let manifest = Manifest::load(&cfg.artifacts)?;
    let shape = ModelShape { patch: manifest.patch, n_ctx: manifest.n_ctx };
    match cfg.backend.as_str() {
        "native" => {
            // Load the base pair once; every replica is a `replicate()`
            // over the same `Arc` storage (pointers, not floats).
            let (base_t, base_d) = NativeBackend::pair_from_manifest(&manifest)?;
            let builder: ReplicaBuilder = Arc::new(move |_r| {
                Ok(ReplicaStacks {
                    target: Box::new(base_t.replicate()?),
                    draft: Box::new(base_d.replicate()?),
                })
            });
            Ok((shape, builder))
        }
        "xla" => {
            let artifacts = cfg.artifacts.clone();
            let kernel = cfg.kernel.clone();
            let builder: ReplicaBuilder = Arc::new(move |_r| {
                // All PJRT state is created on (and never leaves) the
                // replica thread.
                let manifest = Manifest::load(&artifacts)?;
                let mut engine = Engine::cpu()?;
                let t = XlaBackend::load(&mut engine, &manifest, "target", &kernel)?;
                let d = XlaBackend::load(&mut engine, &manifest, "draft", &kernel)?;
                Ok(ReplicaStacks { target: Box::new(t), draft: Box::new(d) })
            });
            Ok((shape, builder))
        }
        other => anyhow::bail!("unknown backend {other}"),
    }
}

/// Validate + normalize one request into (history, n_hist, horizon).
fn prep(
    req: &ForecastRequest,
    shape: ModelShape,
    gamma: usize,
) -> Result<(Vec<f32>, usize, usize), String> {
    let p = shape.patch;
    if req.history.len() % p != 0 {
        return Err(format!(
            "history length {} not a multiple of patch {p}",
            req.history.len()
        ));
    }
    let n_hist = req.history.len() / p;
    // Keep at most the context the models can see during a round.
    let keep = shape.n_ctx.saturating_sub(gamma + 1).max(1);
    let hist = if n_hist > keep {
        req.history[(n_hist - keep) * p..].to_vec()
    } else {
        req.history.clone()
    };
    let n = hist.len() / p;
    Ok((hist, n, req.horizon))
}

/// Record one served request's latency into the overall and per-priority
/// histograms, and fold its deadline outcome into the per-priority SLO
/// counters/gauges.
fn observe_served(shared: &SchedShared, qj: &QueuedJob, latency: Duration) {
    let m = &shared.metrics;
    m.observe("request_latency", latency);
    let prio = qj.priority.as_str();
    m.observe(&format!("request_latency_{prio}"), latency);
    if let Some(dl) = qj.deadline_ms {
        // Shed/expired jobs record their (missed) outcome in the queue;
        // this is the served side of the same ledger.
        m.record_deadline_outcome(prio, latency <= Duration::from_millis(dl));
    }
}

/// Execute one scheduled batch on a replica's stacks: a lockstep
/// speculative decode for an SD group, per-job AR decodes for singles.
#[allow(clippy::too_many_arguments)]
pub(crate) fn execute_batch(
    cfg: &ServeConfig,
    shape: ModelShape,
    target: &dyn Backend,
    draft: &dyn Backend,
    key: GroupKey,
    jobs: Vec<QueuedJob>,
    shared: &SchedShared,
    replica: usize,
) {
    match key {
        GroupKey::Single => {
            for qj in jobs {
                run_single(cfg, shape, target, draft, qj, shared, replica);
            }
        }
        GroupKey::Sd { gamma, k, sigma_bits, cache, adaptive, kind } => {
            let mut spec = cfg.spec_config();
            spec.gamma = gamma;
            spec.k = k;
            spec.policy.sigma = f64::from_bits(sigma_bits);
            spec.cache = if cache { CacheMode::On } else { CacheMode::Off };
            spec.draft.kind = kind;
            spec.adaptive = if adaptive { Some(cfg.adaptive_cfg) } else { None };
            let ctrl = if adaptive { shared.controller.as_deref() } else { None };
            if k > 1 {
                run_tree_group(cfg, shape, target, draft, jobs, &spec, shared, ctrl, replica);
            } else {
                if let Some(a) = spec.adaptive.as_mut() {
                    // The lockstep batched engine spends the batch axis
                    // on sequences, not branches: it only runs k_max = 1
                    // controllers. The fleet controller (fed after the
                    // group) still retunes (γ × k) jointly — a k > 1
                    // recommendation routes *future* admissions to the
                    // tree path above.
                    a.k_max = 1;
                }
                run_sd_group(cfg, shape, target, draft, jobs, &spec, shared, ctrl, replica);
            }
        }
    }
}

#[allow(clippy::too_many_arguments)]
fn run_sd_group(
    cfg: &ServeConfig,
    shape: ModelShape,
    target: &dyn Backend,
    draft: &dyn Backend,
    jobs: Vec<QueuedJob>,
    spec: &SpecConfig,
    shared: &SchedShared,
    controller: Option<&Mutex<GammaController>>,
    replica: usize,
) {
    let metrics = &shared.metrics;
    // Validate all; drop invalid with error replies.
    let mut ok_jobs: Vec<QueuedJob> = Vec::new();
    let mut preps: Vec<(Vec<f32>, usize, usize)> = Vec::new();
    for qj in jobs {
        match prep(&qj.job.req, shape, spec.gamma) {
            Ok(p) => {
                preps.push(p);
                ok_jobs.push(qj);
            }
            Err(e) => {
                metrics.errors_total.fetch_add(1, Ordering::Relaxed);
                let _ = qj.job.reply.send(Err(ServeError::Invalid(e)));
            }
        }
    }
    if ok_jobs.is_empty() {
        return;
    }
    let tasks: Vec<(&[f32], usize, usize)> =
        preps.iter().map(|(h, n, hz)| (h.as_slice(), *n, *hz)).collect();
    // One decode seed per request: the response becomes a pure function
    // of the request, independent of batching, replica count, and
    // arrival order (the scheduler's determinism contract).
    let seeds: Vec<u64> =
        ok_jobs.iter().map(|qj| qj.job.req.seed.unwrap_or(cfg.seed)).collect();
    // Build the group's draft source explicitly so learned state can be
    // threaded across groups and replicas: seed fresh sources with the
    // fleet's current merged head, merge the export back after.
    let mut source = match make_batch_source(&spec.draft, draft) {
        Ok(s) => s,
        Err(e) => {
            for qj in ok_jobs {
                metrics.errors_total.fetch_add(1, Ordering::Relaxed);
                let _ = qj
                    .job
                    .reply
                    .send(Err(ServeError::Internal(format!("draft source failed: {e:#}"))));
            }
            return;
        }
    };
    if let Some(h) = shared.head_for(spec.draft.kind) {
        if let Err(e) = source.import_head(&h) {
            log::warn!("stale draft head discarded: {e:#}");
            shared.discard_head(spec.draft.kind);
        }
    }
    let t0 = Instant::now();
    match sd_generate_stream_seeded(target, source.as_mut(), &tasks, &seeds, usize::MAX, spec) {
        Ok(outs) => {
            if let Some(h) = source.export_head() {
                shared.merge_head(spec.draft.kind, h);
            }
            let batch_wall = t0.elapsed();
            // Feed the finished group back into the server's long-lived
            // controller: every round (including rejected ones) updates
            // α̂/c, and the next batch's adaptive jobs will key on the
            // possibly-retuned γ — whichever replica they land on.
            if let Some(ctrl) = controller {
                let mut c = ctrl.lock().unwrap();
                for out in &outs {
                    for r in &out.rounds {
                        c.observe_round(r);
                    }
                }
                let s = c.state();
                drop(c);
                metrics.set_gauge("controller_gamma", s.gamma as f64);
                metrics.set_gauge("controller_k", s.k as f64);
                metrics.set_gauge("controller_alpha_hat", s.alpha_hat);
                metrics.set_gauge("controller_c", s.c);
                metrics.set_gauge("controller_rounds", s.rounds as f64);
                metrics.set_gauge("controller_gamma_changes", s.gamma_changes as f64);
                metrics.set_gauge("controller_k_changes", s.k_changes as f64);
            }
            // Per-draft-source serving aggregates (see PR 4): EWMA α̂/c
            // per kind plus monotone decode/update counts.
            let kind = spec.draft.kind.as_str();
            let mut agg = DecodeStats::default();
            for out in &outs {
                agg.merge(&out.stats);
            }
            metrics.inc(&format!("draft_{kind}_decodes"), outs.len() as u64);
            metrics.inc(&format!("draft_{kind}_updates"), agg.draft_updates as u64);
            metrics.ewma_gauge(&format!("draft_{kind}_alpha_hat"), agg.alpha_hat(), 0.8);
            metrics.ewma_gauge(&format!("draft_{kind}_c"), agg.cost_ratio(), 0.8);
            for (qj, out) in ok_jobs.into_iter().zip(outs) {
                let latency = qj.job.enqueued.elapsed();
                observe_served(shared, &qj, latency);
                metrics.observe("decode_latency", batch_wall);
                metrics
                    .patches_total
                    .fetch_add(out.patches.len() as u64 / shape.patch as u64, Ordering::Relaxed);
                let alpha = out.stats.alpha_hat();
                if alpha.is_finite() {
                    shared.monitor.record(alpha);
                }
                let resp = ForecastResponse {
                    forecast: out.patches,
                    mode: "sd".into(),
                    draft: spec.draft.kind.as_str().into(),
                    priority: qj.priority.as_str().into(),
                    replica,
                    seed: qj.job.req.seed.unwrap_or(cfg.seed),
                    latency_ms: latency.as_secs_f64() * 1e3,
                    alpha_hat: alpha,
                    mean_block_len: out.stats.mean_block_len(),
                    rounds: out.stats.rounds,
                    draft_calls: out.stats.draft_calls,
                    target_calls: out.stats.target_calls,
                };
                let _ = qj.job.reply.send(Ok(resp));
            }
        }
        Err(e) => {
            for qj in ok_jobs {
                metrics.errors_total.fetch_add(1, Ordering::Relaxed);
                let _ = qj
                    .job
                    .reply
                    .send(Err(ServeError::Internal(format!("decode failed: {e:#}"))));
            }
        }
    }
}

/// Execute a k > 1 group as per-job tree decodes. Tree speculation
/// spends the target's batch axis on candidate branches, so jobs in the
/// group run sequentially through [`sd_generate_tree_from`] — each with
/// its own seed and draft source, keeping the response a pure function
/// of the request exactly like the lockstep path. Learned draft heads
/// thread through the fleet snapshot the same way, and adaptive groups
/// feed every round back into the long-lived (γ × k) controller.
#[allow(clippy::too_many_arguments)]
fn run_tree_group(
    cfg: &ServeConfig,
    shape: ModelShape,
    target: &dyn Backend,
    draft: &dyn Backend,
    jobs: Vec<QueuedJob>,
    spec: &SpecConfig,
    shared: &SchedShared,
    controller: Option<&Mutex<GammaController>>,
    replica: usize,
) {
    let metrics = &shared.metrics;
    metrics.set_gauge("tree_k", spec.k as f64);
    let kind = spec.draft.kind.as_str();
    for qj in jobs {
        let (hist, n_hist, horizon) = match prep(&qj.job.req, shape, spec.gamma) {
            Ok(p) => p,
            Err(e) => {
                metrics.errors_total.fetch_add(1, Ordering::Relaxed);
                let _ = qj.job.reply.send(Err(ServeError::Invalid(e)));
                continue;
            }
        };
        let mut source = match make_source(&spec.draft, draft) {
            Ok(s) => s,
            Err(e) => {
                metrics.errors_total.fetch_add(1, Ordering::Relaxed);
                let _ = qj
                    .job
                    .reply
                    .send(Err(ServeError::Internal(format!("draft source failed: {e:#}"))));
                continue;
            }
        };
        if let Some(h) = shared.head_for(spec.draft.kind) {
            if let Err(e) = source.import_head(&h) {
                log::warn!("stale draft head discarded: {e:#}");
                shared.discard_head(spec.draft.kind);
            }
        }
        let mut job_spec = *spec;
        job_spec.seed = qj.job.req.seed.unwrap_or(cfg.seed);
        let t0 = Instant::now();
        match sd_generate_tree_from(target, source.as_mut(), &hist, n_hist, horizon, &job_spec) {
            Ok(out) => {
                if let Some(h) = source.export_head() {
                    shared.merge_head(spec.draft.kind, h);
                }
                let wall = t0.elapsed();
                metrics.inc("tree_decodes", 1);
                metrics.inc("tree_rounds", out.stats.rounds as u64);
                metrics.inc("tree_branches_verified", out.stats.branches_verified as u64);
                // Winner-depth histogram: how deep the committed branch
                // ran, per tree round (capped — the tail folds into the
                // last bucket).
                for r in &out.rounds {
                    if r.branches > 1 {
                        metrics.inc(&format!("tree_winner_depth_{}", r.accepted.min(8)), 1);
                    }
                }
                if let Some(ctrl) = controller {
                    let mut c = ctrl.lock().unwrap();
                    for r in &out.rounds {
                        c.observe_round(r);
                    }
                    let s = c.state();
                    drop(c);
                    metrics.set_gauge("controller_gamma", s.gamma as f64);
                    metrics.set_gauge("controller_k", s.k as f64);
                    metrics.set_gauge("controller_alpha_hat", s.alpha_hat);
                    metrics.set_gauge("controller_c", s.c);
                    metrics.set_gauge("controller_rounds", s.rounds as f64);
                    metrics.set_gauge("controller_gamma_changes", s.gamma_changes as f64);
                    metrics.set_gauge("controller_k_changes", s.k_changes as f64);
                }
                metrics.inc(&format!("draft_{kind}_decodes"), 1);
                metrics.inc(&format!("draft_{kind}_updates"), out.stats.draft_updates as u64);
                metrics.ewma_gauge(&format!("draft_{kind}_alpha_hat"), out.stats.alpha_hat(), 0.8);
                metrics.ewma_gauge(&format!("draft_{kind}_c"), out.stats.cost_ratio(), 0.8);
                let latency = qj.job.enqueued.elapsed();
                observe_served(shared, &qj, latency);
                metrics.observe("decode_latency", wall);
                metrics
                    .patches_total
                    .fetch_add(out.patches.len() as u64 / shape.patch as u64, Ordering::Relaxed);
                let alpha = out.stats.alpha_hat();
                if alpha.is_finite() {
                    shared.monitor.record(alpha);
                }
                let resp = ForecastResponse {
                    forecast: out.patches,
                    mode: "sd".into(),
                    draft: kind.into(),
                    priority: qj.priority.as_str().into(),
                    replica,
                    seed: job_spec.seed,
                    latency_ms: latency.as_secs_f64() * 1e3,
                    alpha_hat: alpha,
                    mean_block_len: out.stats.mean_block_len(),
                    rounds: out.stats.rounds,
                    draft_calls: out.stats.draft_calls,
                    target_calls: out.stats.target_calls,
                };
                let _ = qj.job.reply.send(Ok(resp));
            }
            Err(e) => {
                metrics.errors_total.fetch_add(1, Ordering::Relaxed);
                let _ = qj
                    .job
                    .reply
                    .send(Err(ServeError::Internal(format!("tree decode failed: {e:#}"))));
            }
        }
    }
}

fn run_single(
    cfg: &ServeConfig,
    shape: ModelShape,
    target: &dyn Backend,
    draft: &dyn Backend,
    qj: QueuedJob,
    shared: &SchedShared,
    replica: usize,
) {
    let metrics = &shared.metrics;
    let model: &dyn Backend = match qj.job.req.mode {
        Mode::DraftOnly => draft,
        _ => target,
    };
    let cache =
        if qj.job.req.cache.unwrap_or(cfg.cache) { CacheMode::On } else { CacheMode::Off };
    let result = (|| -> Result<ForecastResponse, ServeError> {
        let (hist, n_hist, horizon) =
            prep(&qj.job.req, shape, 1).map_err(ServeError::Invalid)?;
        let (pred, _wall, calls) = ar_decode_with(model, &hist, n_hist, horizon, cache)
            .map_err(|e| ServeError::Internal(format!("{e:#}")))?;
        let latency = qj.job.enqueued.elapsed();
        observe_served(shared, &qj, latency);
        metrics.patches_total.fetch_add(horizon as u64, Ordering::Relaxed);
        Ok(ForecastResponse {
            forecast: pred,
            mode: if qj.job.req.mode == Mode::DraftOnly { "draft" } else { "baseline" }.into(),
            // AR modes draft nothing; the field names the proposal source
            // of SD decodes only.
            draft: String::new(),
            priority: qj.priority.as_str().into(),
            replica,
            seed: qj.job.req.seed.unwrap_or(cfg.seed),
            latency_ms: latency.as_secs_f64() * 1e3,
            alpha_hat: f64::NAN,
            mean_block_len: f64::NAN,
            rounds: horizon,
            draft_calls: if qj.job.req.mode == Mode::DraftOnly { calls } else { 0 },
            target_calls: if qj.job.req.mode == Mode::DraftOnly { 0 } else { calls },
        })
    })();
    if result.is_err() {
        metrics.errors_total.fetch_add(1, Ordering::Relaxed);
    }
    let _ = qj.job.reply.send(result);
}
