//! Admission + execution glue of the serving tier.
//!
//! HTTP workers call [`BatcherHandle::forecast`]: the request is keyed by
//! its decode-compatibility group, stamped with priority/deadline, and
//! admitted into the bounded [`AdmissionQueue`] (sched subsystem). Engine
//! replicas pull EDF-ordered batches from the queue and run them through
//! [`execute_batch`]: one lockstep speculative decode per k = 1 SD group
//! (per-request seeds through [`sd_generate_stream_seeded`], so responses
//! are replica- and batching-invariant), per-job tree decodes for k > 1
//! groups (the batch axis is spent on candidate branches — see
//! [`sd_generate_tree_from`]), individual AR decodes for the baseline
//! modes. Replies travel per-job channels, typed as
//! [`ServeError`] so the HTTP layer can map shed/expired/invalid/internal
//! to distinct statuses.
//!
//! The pre-scheduler single-FIFO engine loop is gone; `start_engine`
//! now stands up the scheduler (queue + replica pool) and returns a
//! handle with the same surface the HTTP router always used.
//!
//! Fault tolerance: every scheduled group runs inside a [`GroupRun`]
//! holder, so when a decode panics (a real bug, or an injected fault
//! from [`crate::faultinject`]) the replica's supervisor can still
//! reach each unreplied job — the poisoned job is answered with a typed
//! [`ServeError::ReplicaFailure`], innocent group-mates are requeued
//! exactly once, and no client ever waits out the engine timeout
//! because a reply channel unwound. Decode errors carrying the engine
//! numeric guards' "non-finite" marker are counted and reported to the
//! speculation circuit breaker; while the breaker is open, adaptive
//! admissions key at γ = 0 and route to [`run_ar_fallback_group`] —
//! pure-AR service on the target model that ticks the breaker's
//! cool-down until its half-open probes re-enable speculation.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Mutex, MutexGuard};
use std::time::{Duration, Instant};

use anyhow::Result;

use super::protocol::{ForecastRequest, ForecastResponse, Mode, ServeError};
use super::sched::{
    start_pool, AdmissionQueue, GroupKey, ModelShape, ModelSlot, QueuedJob, ReplicaBuilder,
    ReplicaStacks, SchedShared,
};
use crate::config::{ServeConfig, SwapHeads};
use crate::faultinject::FaultPlan;
use crate::forecast::ar_decode_with;
use crate::metrics::{AcceptanceMonitor, Metrics};
use crate::models::{Backend, CacheMode, NativeBackend, XlaBackend};
use crate::registry::{self, Registry};
use crate::runtime::{Engine, Manifest};
use crate::specdec::{
    make_batch_source, make_source, sd_generate_stream_seeded, sd_generate_tree_from,
    with_round_observer, ControllerState, DecodeStats, DraftKind, GammaController, RoundObserver,
    RoundStats, SpecConfig,
};
use crate::trace::{EventKind, TraceSink, MAX_TRACE_ALPHAS};

/// Lock a shared mutex, tolerating poison: a replica panic (induced by
/// the chaos plan or a real bug) must not brick the fleet's controller
/// or draft-head state for every future request. Writers keep the
/// guarded values internally consistent (worst case: a partially-fed
/// controller round), which is strictly better than serving errors
/// forever off a poisoned lock.
pub(crate) fn lock_ignore_poison<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|e| e.into_inner())
}

/// One queued forecast request plus its reply channel.
pub struct Job {
    /// The parsed request.
    pub req: ForecastRequest,
    /// Enqueue time (request latency and deadlines are measured from
    /// here).
    pub enqueued: Instant,
    /// Channel the executing replica (or the queue, for shed/expired
    /// jobs) answers on.
    pub reply: mpsc::SyncSender<Result<ForecastResponse, ServeError>>,
}

/// Handle held by the HTTP side.
#[derive(Clone)]
pub struct BatcherHandle {
    cfg: Arc<ServeConfig>,
    shape: ModelShape,
    queue: Arc<AdmissionQueue>,
    /// The pool's live model binding (builder + identity + generation);
    /// [`BatcherHandle::swap_model`] retargets it.
    slot: Arc<ModelSlot>,
    /// The cross-replica shared state ([`SwapHeads::Reset`] clears its
    /// draft heads on swap).
    shared: Arc<SchedShared>,
    /// Shared metrics registry (also rendered at `/metrics`).
    pub metrics: Arc<Metrics>,
    /// Windowed acceptance monitor (alerting; paper §7).
    pub monitor: Arc<AcceptanceMonitor>,
    /// The server's long-lived adaptive γ controller, present when
    /// `ServeConfig::adaptive` is on. Its recommendation seeds each
    /// adaptive decode group (so jobs regroup as γ drifts) and every
    /// finished group's rounds are fed back — from whichever replica ran
    /// them. Exposed read-only via `/stats`.
    pub controller: Option<Arc<Mutex<GammaController>>>,
    /// The server's default draft-source kind (per-request `"draft"`
    /// overrides route jobs to other kinds; `/stats` reports per-kind
    /// aggregates).
    pub draft: DraftKind,
    /// The live fault-injection schedule, when chaos is armed
    /// (`ServeConfig::fault.enabled`). `/stats` reports its counters.
    pub fault: Option<Arc<FaultPlan>>,
    /// The flight recorder, when `ServeConfig::trace_capacity > 0`
    /// (`None` = tracing disabled and every trace site is a no-op).
    /// `/debug/trace` and `/debug/requests/<id>` render it; `/stats`
    /// reports its counters.
    pub trace: Option<Arc<TraceSink>>,
}

impl BatcherHandle {
    /// Synchronous request-response (the HTTP worker blocks here).
    /// Admission failures (shed / invalid) return immediately; admitted
    /// jobs wait for their replica's reply.
    pub fn forecast(&self, req: ForecastRequest) -> Result<ForecastResponse, ServeError> {
        self.forecast_with_id(req).1
    }

    /// [`BatcherHandle::forecast`], additionally returning the request's
    /// id (client-supplied, or assigned here) — the HTTP layer stamps it
    /// into `X-Request-Id` and error bodies even when the request fails
    /// before a response object exists.
    pub fn forecast_with_id(
        &self,
        req: ForecastRequest,
    ) -> (u64, Result<ForecastResponse, ServeError>) {
        self.metrics.requests_total.fetch_add(1, Ordering::Relaxed);
        let mut req = req;
        // Request identity: every request carries an id from admission
        // to reply (trace events, the response body, and `X-Request-Id`
        // all agree on it). Client-supplied ids are kept; assigned ids
        // follow the same splitmix discipline as decode seeds, so a
        // seeded server hands out a deterministic id sequence. Id 0 is
        // reserved for control-plane trace events and never assigned.
        if req.request_id.is_none() {
            static RID_SEQ: std::sync::atomic::AtomicU64 = std::sync::atomic::AtomicU64::new(0);
            req.request_id = Some(
                self.cfg
                    .seed
                    .wrapping_add(RID_SEQ.fetch_add(1, Ordering::Relaxed))
                    .wrapping_mul(0x9E37_79B9_7F4A_7C15)
                    .max(1),
            );
        }
        let rid = req.request_id.unwrap_or(0);
        // Seed discipline: a request that pins a seed is exactly
        // reproducible (bit-identical to `sd_generate_from` at that
        // seed, any replica count). Unseeded requests get a fresh
        // decode seed here — without this, all unseeded traffic would
        // share one RNG stream and `"sampled"` clients repeating a
        // request would receive N copies of one draw instead of N
        // samples. The assigned seed is echoed in the response, so any
        // served forecast can be replayed afterwards.
        if req.seed.is_none() {
            static REQ_SEQ: std::sync::atomic::AtomicU64 = std::sync::atomic::AtomicU64::new(0);
            req.seed = Some(
                self.cfg
                    .seed
                    .wrapping_add(REQ_SEQ.fetch_add(1, Ordering::Relaxed))
                    .wrapping_mul(0x9E37_79B9_7F4A_7C15),
            );
        }
        let key = match self.group_key(&req) {
            Ok(k) => k,
            Err(e) => return (rid, Err(e)),
        };
        let priority = req.priority;
        let deadline_ms = req.deadline_ms.or(if self.cfg.default_deadline_ms > 0 {
            Some(self.cfg.default_deadline_ms)
        } else {
            None
        });
        let (tx, rx) = mpsc::sync_channel(1);
        let enqueued = Instant::now();
        let job = Job { req, enqueued, reply: tx };
        if let Err(e) = self.queue.admit(job, priority, deadline_ms, key) {
            return (rid, Err(e));
        }
        let result = match rx.recv_timeout(Duration::from_secs(120)) {
            Ok(r) => r,
            Err(_) => Err(ServeError::Internal("engine timeout".into())),
        };
        // The request's root span: admission to reply, tagged with the
        // outcome. Shed/expired requests already carried their terminal
        // event from the queue; this span still lands for them, so every
        // admitted request's timeline ends the same way.
        if let Some(t) = &self.shared.trace {
            let (ok, status, rounds) = match &result {
                Ok(r) => (true, 200u16, r.rounds as u32),
                Err(e) => (false, e.http_status(), 0),
            };
            t.record_span_ending_now(
                rid,
                enqueued.elapsed(),
                EventKind::Replied { ok, status, rounds },
            );
        }
        (rid, result)
    }

    /// Compute the request's decode-compatibility group (and reject the
    /// combinations the server cannot honor, before they cost a queue
    /// slot).
    fn group_key(&self, req: &ForecastRequest) -> Result<GroupKey, ServeError> {
        let cfg = &self.cfg;
        match req.mode {
            Mode::Sd if !cfg.baseline => {
                // Asking for adaptation on a server that runs without a
                // controller is a request we cannot honor — reject it
                // rather than silently serving static gamma.
                if req.adaptive == Some(true) && self.controller.is_none() {
                    self.metrics.errors_total.fetch_add(1, Ordering::Relaxed);
                    return Err(ServeError::Invalid(
                        "adaptive speculation is not enabled on this server \
                         (start it with --adaptive)"
                            .to_string(),
                    ));
                }
                let kind = req.draft.unwrap_or(cfg.draft.kind);
                // The long-lived controller's α̂/c telemetry is
                // per-source: rounds from a different draft kind would
                // contaminate the estimates the default kind's γ is
                // tuned from. Jobs overriding the draft kind cannot ride
                // the controller.
                if req.adaptive == Some(true) && kind != cfg.draft.kind {
                    self.metrics.errors_total.fetch_add(1, Ordering::Relaxed);
                    return Err(ServeError::Invalid(format!(
                        "adaptive speculation rides the server's long-lived \
                         controller, which is tuned for draft '{}'; drop the \
                         per-request draft override or the adaptive flag",
                        cfg.draft.kind.as_str()
                    )));
                }
                // An explicit per-request gamma (or k) always pins the
                // job to the static path: a pinned request is a pinned
                // request.
                let adaptive = self.controller.is_some()
                    && req.adaptive.unwrap_or(cfg.adaptive)
                    && req.gamma.is_none()
                    && req.k.is_none()
                    && kind == cfg.draft.kind;
                let (gamma, k) = if adaptive {
                    // An open circuit breaker keys adaptive jobs at
                    // γ = 0 here, routing them to the pure-AR fallback
                    // group in `execute_batch`.
                    let ctrl = lock_ignore_poison(self.controller.as_ref().unwrap());
                    (ctrl.gamma_for(self.shape.n_ctx), ctrl.k())
                } else {
                    (req.gamma.unwrap_or(cfg.gamma), req.k.unwrap_or(cfg.k))
                };
                // Lossless decoding is proven only for k = 1 (the
                // equivalence wall); a per-request k override cannot
                // widen a lossless server's tree.
                if k > 1 && cfg.lossless {
                    self.metrics.errors_total.fetch_add(1, Ordering::Relaxed);
                    return Err(ServeError::Invalid(
                        "tree speculation (k > 1) requires the practical \
                         variant; this server runs lossless decoding"
                            .to_string(),
                    ));
                }
                let sigma = req.sigma.unwrap_or(cfg.sigma);
                let cache = req.cache.unwrap_or(cfg.cache);
                Ok(GroupKey::Sd { gamma, k, sigma_bits: sigma.to_bits(), cache, adaptive, kind })
            }
            _ => Ok(GroupKey::Single),
        }
    }

    /// Jobs currently waiting in the admission queue.
    pub fn queue_depth(&self) -> usize {
        self.queue.depth()
    }

    /// The admission queue's hard cap.
    pub fn queue_cap(&self) -> usize {
        self.queue.cap()
    }

    /// Readiness: false while the admission queue is saturated (the
    /// `/healthz` 503 signal for external load balancers).
    pub fn ready(&self) -> bool {
        !self.queue.saturated()
    }

    /// The scheduler's dispatch policy name (`"edf"` / `"fifo"`).
    pub fn sched_policy(&self) -> &'static str {
        self.queue.policy().as_str()
    }

    /// Engine replicas serving this queue.
    pub fn replicas(&self) -> usize {
        self.cfg.replicas
    }

    /// Begin a graceful drain: refuse new admissions with a typed
    /// [`ServeError::Draining`] (HTTP 503) while replicas keep serving
    /// what is already queued. `/healthz` reports the draining state so
    /// load balancers stop routing here.
    pub fn begin_drain(&self) {
        self.queue.begin_drain();
        self.metrics.set_gauge("draining", 1.0);
    }

    /// True once a graceful drain has begun.
    pub fn draining(&self) -> bool {
        self.queue.is_draining()
    }

    /// Stop the scheduler: refuse new admissions, fail queued jobs, and
    /// let the replica threads drain out.
    pub fn shutdown(&self) {
        self.queue.shutdown();
    }

    /// Open the server's registry root (`ServeConfig::registry_root`),
    /// creating its directories on first use — servers that never see a
    /// registry route touch nothing on disk.
    pub fn registry(&self) -> Result<Registry, ServeError> {
        Registry::open(&self.cfg.registry_root()).map_err(ServeError::from)
    }

    /// The serving model's registry manifest digest (`"unregistered"`
    /// when the pool was built from artifacts or an injected builder).
    pub fn model_digest(&self) -> String {
        self.slot.digest()
    }

    /// The serving model's display reference (`name:version`).
    pub fn model_label(&self) -> String {
        self.slot.label()
    }

    /// Pool model generation (0 = boot weights; +1 per completed swap).
    pub fn model_generation(&self) -> u64 {
        self.slot.generation()
    }

    /// Live weight swap (`POST /admin/swap`): resolve `reference`
    /// against the configured registry, verify + zero-copy-load both
    /// roles, then retarget the pool — the slot takes the new builder,
    /// the queue's interrupt epoch wakes parked replicas, and each
    /// replica rebinds between decode batches. Queued jobs stay queued
    /// and in-flight groups finish on the old weights, so a swap drops
    /// zero requests. Blocks until every replica acknowledges the new
    /// generation (or the barrier times out — stragglers still rebind
    /// before their next batch). Draft heads and γ/k-controller state
    /// follow `ServeConfig::swap_heads` (reset or carry).
    ///
    /// A failed resolve/verify/load leaves the pool serving exactly what
    /// it served before: the slot is only retargeted after the new pair
    /// is fully loaded.
    pub fn swap_model(&self, reference: &str) -> Result<SwapReport, ServeError> {
        let start = Instant::now();
        let fail = |e: ServeError| -> ServeError {
            self.metrics.inc("model_swap_failed", 1);
            e
        };
        let registry = Registry::open(&self.cfg.registry_root())
            .map_err(|e| fail(ServeError::from(e)))?;
        let pair = registry::load_pair(&registry, reference)
            .map_err(|e| fail(ServeError::from(e)))?;
        // Sessions, scratch arenas, and request validation are all sized
        // by the boot shape; a swap changes weights, not geometry.
        if pair.manifest.patch != self.shape.patch || pair.manifest.n_ctx != self.shape.n_ctx {
            return Err(fail(ServeError::Invalid(format!(
                "manifest {reference} has shape patch={} n_ctx={}, pool is serving \
                 patch={} n_ctx={} — live swap cannot change model geometry",
                pair.manifest.patch, pair.manifest.n_ctx, self.shape.patch, self.shape.n_ctx
            ))));
        }
        let label = format!("{}:{}", pair.manifest.name, pair.manifest.version);
        let digest = pair.manifest_digest.clone();
        let (base_t, base_d) = (pair.target, pair.draft);
        let builder: ReplicaBuilder = Arc::new(move |_r| {
            Ok(ReplicaStacks {
                target: Box::new(base_t.replicate()?),
                draft: Box::new(base_d.replicate()?),
            })
        });
        // Heads/controller policy, applied before replicas wake: under
        // Reset the learned residual heads (fit against the *old*
        // target's means) and the controller's α̂/c estimates are
        // discarded so the new weights start from the configured
        // defaults; under Carry both survive the cutover.
        let heads = self.cfg.swap_heads;
        if heads == SwapHeads::Reset {
            lock_ignore_poison(&self.shared.draft_heads).clear();
            if let Some(c) = &self.controller {
                let mut ctrl = lock_ignore_poison(c);
                *ctrl = GammaController::new(self.cfg.adaptive_cfg, self.cfg.gamma, self.cfg.sigma);
                ctrl.set_draft_kind(self.cfg.draft.kind.as_str());
            }
        }
        let generation = self.slot.swap(builder, &digest, &label);
        if let Some(t) = &self.shared.trace {
            t.record(0, EventKind::Swap { generation });
        }
        self.queue.bump_epoch();
        let complete =
            self.slot.wait_generation(generation, self.cfg.replicas, SWAP_BARRIER_TIMEOUT);
        let rebound = self.slot.replicas_at(generation);
        self.metrics.inc("model_swap_total", 1);
        if !complete {
            self.metrics.inc("model_swap_incomplete", 1);
        }
        self.metrics.set_gauge("model_generation", generation as f64);
        self.metrics.observe("model_swap", start.elapsed());
        Ok(SwapReport {
            digest,
            label,
            generation,
            replicas: self.cfg.replicas,
            rebound,
            complete,
            duration_ms: start.elapsed().as_millis() as u64,
            heads: heads.as_str(),
        })
    }
}

/// How long [`BatcherHandle::swap_model`] waits for every replica to
/// acknowledge the new generation. A replica wedged past this (e.g. by
/// injected chaos stalls) does not block the swap — it rebinds before
/// its next batch; the report carries `complete: false`.
const SWAP_BARRIER_TIMEOUT: Duration = Duration::from_secs(30);

/// Outcome of one live weight swap — the `/admin/swap` reply body.
pub struct SwapReport {
    /// New serving manifest digest (content address).
    pub digest: String,
    /// New serving reference (`name:version`).
    pub label: String,
    /// Pool generation after the swap.
    pub generation: u64,
    /// Replica count the barrier waited on.
    pub replicas: usize,
    /// Replicas that acknowledged the new generation before the barrier
    /// released.
    pub rebound: usize,
    /// True when every replica acknowledged within the barrier timeout.
    pub complete: bool,
    /// Wall clock from verify start to barrier exit.
    pub duration_ms: u64,
    /// Heads/controller policy applied (`"reset"` / `"carry"`).
    pub heads: &'static str,
}

/// Spawn the scheduler (admission queue + replica pool) from the
/// artifacts manifest; blocks until every replica's backends are loaded
/// (or fails).
pub fn start_engine(
    cfg: ServeConfig,
    metrics: Arc<Metrics>,
    monitor: Arc<AcceptanceMonitor>,
    stop: Arc<AtomicBool>,
) -> Result<(BatcherHandle, Vec<std::thread::JoinHandle<()>>)> {
    if let Some(reference) = cfg.registry_model.clone() {
        // Registry boot: resolve + verify + zero-copy-load the pair and
        // serve under its manifest digest from the first request.
        let (shape, builder, digest, label) = builder_from_registry(&cfg, &reference)?;
        let slot = Arc::new(ModelSlot::new(builder, &digest, &label));
        return start_engine_with_slot(cfg, shape, slot, metrics, monitor, stop);
    }
    let (shape, builder) = builder_from_artifacts(&cfg)?;
    start_engine_with_builder(cfg, shape, builder, metrics, monitor, stop)
}

/// [`start_engine`] with an injected replica builder — the entry point
/// that lets tests and benches run the complete serving stack (HTTP,
/// admission, EDF dispatch, replica pool) over synthetic in-memory
/// models, no artifacts directory required. The pool serves with the
/// `"unregistered"` model identity until a swap retargets it.
pub fn start_engine_with_builder(
    cfg: ServeConfig,
    shape: ModelShape,
    builder: ReplicaBuilder,
    metrics: Arc<Metrics>,
    monitor: Arc<AcceptanceMonitor>,
    stop: Arc<AtomicBool>,
) -> Result<(BatcherHandle, Vec<std::thread::JoinHandle<()>>)> {
    let slot = Arc::new(ModelSlot::new(builder, "unregistered", "builtin"));
    start_engine_with_slot(cfg, shape, slot, metrics, monitor, stop)
}

fn start_engine_with_slot(
    cfg: ServeConfig,
    shape: ModelShape,
    slot: Arc<ModelSlot>,
    metrics: Arc<Metrics>,
    monitor: Arc<AcceptanceMonitor>,
    stop: Arc<AtomicBool>,
) -> Result<(BatcherHandle, Vec<std::thread::JoinHandle<()>>)> {
    let controller = if cfg.adaptive {
        let mut ctrl = GammaController::new(cfg.adaptive_cfg, cfg.gamma, cfg.sigma);
        // Tag the telemetry with the server's default source: the c this
        // controller measures (and the γ it recommends) is per-source.
        ctrl.set_draft_kind(cfg.draft.kind.as_str());
        Some(Arc::new(Mutex::new(ctrl)))
    } else {
        None
    };
    let draft_kind = cfg.draft.kind;
    // Arm the chaos plan only when the config gates it on; a disabled
    // config never constructs a plan and the serving path is untouched.
    let fault = if cfg.fault.enabled {
        Some(FaultPlan::new(cfg.fault).map_err(|e| anyhow::anyhow!("fault config: {e:#}"))?)
    } else {
        None
    };
    // Construct the flight recorder only when configured: with
    // `trace_capacity = 0` (the default) no sink exists, every trace
    // call site is an `if let` on `None`, and serving is bit-identical
    // to an untraced build (the FaultPlan gating pattern).
    let trace = if cfg.trace_capacity > 0 {
        Some(Arc::new(TraceSink::new(cfg.trace_capacity)))
    } else {
        None
    };
    let cfg = Arc::new(cfg);
    let queue = Arc::new(AdmissionQueue::new(
        cfg.queue_cap,
        cfg.sched,
        cfg.retry_after_ms,
        metrics.clone(),
        trace.clone(),
        Arc::clone(&stop),
    ));
    let shared = Arc::new(SchedShared {
        metrics: metrics.clone(),
        monitor: monitor.clone(),
        controller: controller.clone(),
        draft_heads: Mutex::new(BTreeMap::new()),
        fault_plan: fault.clone(),
        trace: trace.clone(),
    });
    // Pre-register the fault-tolerance ledger so `/metrics` scrapes see
    // the counters (at 0) and the breaker gauge before any fault fires.
    for name in [
        "replica_restarts",
        "replica_failures",
        "requeues",
        "numeric_faults",
        "model_swap_total",
        "model_swap_failed",
        "model_swap_incomplete",
        "model_swap_rebinds",
        "model_swap_rebind_failures",
    ] {
        metrics.inc(name, 0);
    }
    metrics.set_gauge("breaker_state", 0.0);
    metrics.set_gauge("draining", 0.0);
    metrics.set_gauge("model_generation", 0.0);
    let handles = start_pool(
        Arc::clone(&cfg),
        shape,
        Arc::clone(&slot),
        Arc::clone(&queue),
        Arc::clone(&shared),
        stop,
    )?;
    Ok((
        BatcherHandle {
            cfg,
            shape,
            queue,
            slot,
            shared,
            metrics,
            monitor,
            controller,
            draft: draft_kind,
            fault,
            trace,
        },
        handles,
    ))
}

/// Resolve `reference` against the configured registry, verify + load
/// both roles (one mmap + one hash pass per blob — see
/// [`registry::load_pair`]), and wrap the pair as a replica builder:
/// each replica's stack is a [`NativeBackend::replicate`] over the
/// mapped `Arc` storage, so N replicas share one copy of the floats and
/// zero floats were heap-copied getting them off disk.
fn builder_from_registry(
    cfg: &ServeConfig,
    reference: &str,
) -> Result<(ModelShape, ReplicaBuilder, String, String)> {
    let reg = Registry::open(&cfg.registry_root())?;
    let pair = registry::load_pair(&reg, reference)?;
    let shape = ModelShape { patch: pair.manifest.patch, n_ctx: pair.manifest.n_ctx };
    let label = format!("{}:{}", pair.manifest.name, pair.manifest.version);
    let digest = pair.manifest_digest.clone();
    let (base_t, base_d) = (pair.target, pair.draft);
    let builder: ReplicaBuilder = Arc::new(move |_r| {
        Ok(ReplicaStacks {
            target: Box::new(base_t.replicate()?),
            draft: Box::new(base_d.replicate()?),
        })
    });
    Ok((shape, builder, digest, label))
}

/// Resolve the manifest into (shape, replica builder). The native
/// backend loads each weight blob **once** here; every replica's stack
/// is a [`NativeBackend::replicate`] over that single `Arc` storage
/// (packing copies pointers, not floats). The xla backend constructs
/// its PJRT state on the replica thread itself (the client is not
/// `Send`) and is limited to one replica by `ServeConfig::validate`.
fn builder_from_artifacts(cfg: &ServeConfig) -> Result<(ModelShape, ReplicaBuilder)> {
    let manifest = Manifest::load(&cfg.artifacts)?;
    let shape = ModelShape { patch: manifest.patch, n_ctx: manifest.n_ctx };
    match cfg.backend.as_str() {
        "native" => {
            // Load the base pair once; every replica is a `replicate()`
            // over the same `Arc` storage (pointers, not floats).
            let (base_t, base_d) = NativeBackend::pair_from_manifest(&manifest)?;
            let builder: ReplicaBuilder = Arc::new(move |_r| {
                Ok(ReplicaStacks {
                    target: Box::new(base_t.replicate()?),
                    draft: Box::new(base_d.replicate()?),
                })
            });
            Ok((shape, builder))
        }
        "xla" => {
            let artifacts = cfg.artifacts.clone();
            let kernel = cfg.kernel.clone();
            let builder: ReplicaBuilder = Arc::new(move |_r| {
                // All PJRT state is created on (and never leaves) the
                // replica thread.
                let manifest = Manifest::load(&artifacts)?;
                let mut engine = Engine::cpu()?;
                let t = XlaBackend::load(&mut engine, &manifest, "target", &kernel)?;
                let d = XlaBackend::load(&mut engine, &manifest, "draft", &kernel)?;
                Ok(ReplicaStacks { target: Box::new(t), draft: Box::new(d) })
            });
            Ok((shape, builder))
        }
        other => anyhow::bail!("unknown backend {other}"),
    }
}

/// Validate + normalize one request into (history, n_hist, horizon).
fn prep(
    req: &ForecastRequest,
    shape: ModelShape,
    gamma: usize,
) -> Result<(Vec<f32>, usize, usize), String> {
    let p = shape.patch;
    if req.history.len() % p != 0 {
        return Err(format!(
            "history length {} not a multiple of patch {p}",
            req.history.len()
        ));
    }
    let n_hist = req.history.len() / p;
    // Keep at most the context the models can see during a round.
    let keep = shape.n_ctx.saturating_sub(gamma + 1).max(1);
    let hist = if n_hist > keep {
        req.history[(n_hist - keep) * p..].to_vec()
    } else {
        req.history.clone()
    };
    let n = hist.len() / p;
    Ok((hist, n, req.horizon))
}

/// Record one served request's latency into the overall and per-priority
/// histograms, and fold its deadline outcome into the per-priority SLO
/// counters/gauges.
fn observe_served(shared: &SchedShared, qj: &QueuedJob, latency: Duration) {
    let m = &shared.metrics;
    m.observe("request_latency", latency);
    let prio = qj.priority.as_str();
    m.observe(&format!("request_latency_{prio}"), latency);
    if let Some(dl) = qj.deadline_ms {
        // Shed/expired jobs record their (missed) outcome in the queue;
        // this is the served side of the same ledger.
        m.record_deadline_outcome(prio, latency <= Duration::from_millis(dl));
    }
}

/// Maps engine round callbacks back to request ids and forwards each
/// completed speculative round into the flight recorder. Installed
/// thread-locally around one decode (`rids[seq]` is the request in
/// batch task order); the per-sequence round counters are fixed-size,
/// so observing allocates nothing after construction.
struct TraceRoundObserver {
    sink: Arc<TraceSink>,
    /// Request id per in-batch sequence index.
    rids: Vec<u64>,
    /// Draft-source code for the whole group (groups are draft-keyed).
    draft: u8,
    /// Per-sequence 0-based round counters.
    rounds: Vec<std::sync::atomic::AtomicU32>,
}

impl TraceRoundObserver {
    fn new(sink: Arc<TraceSink>, rids: Vec<u64>, kind: DraftKind) -> TraceRoundObserver {
        let rounds = (0..rids.len()).map(|_| std::sync::atomic::AtomicU32::new(0)).collect();
        TraceRoundObserver { sink, rids, draft: kind as u8, rounds }
    }
}

impl RoundObserver for TraceRoundObserver {
    fn on_round(&self, seq: usize, r: &RoundStats) {
        let rid = self.rids.get(seq).copied().unwrap_or(0);
        let round =
            self.rounds.get(seq).map(|c| c.fetch_add(1, Ordering::Relaxed)).unwrap_or(0);
        let fan = r.branches.max(1);
        let mut alphas = [0.0f32; MAX_TRACE_ALPHAS];
        let n_alphas = r.alphas.len().min(MAX_TRACE_ALPHAS);
        for (dst, src) in alphas.iter_mut().zip(&r.alphas) {
            *dst = *src as f32;
        }
        self.sink.record_span_ending_now(
            rid,
            r.draft_time + r.target_time,
            EventKind::Round {
                round,
                gamma: r.gamma.min(u8::MAX as usize) as u8,
                k: fan.min(u8::MAX as usize) as u8,
                draft: self.draft,
                proposed: (r.gamma * fan).min(u16::MAX as usize) as u16,
                accepted: r.accepted.min(u16::MAX as usize) as u16,
                rollback: r.gamma.saturating_sub(r.accepted).min(u16::MAX as usize) as u16,
                residual: r.residual_draws.min(u16::MAX as usize) as u16,
                draft_ns: r.draft_time.as_nanos() as u64,
                target_ns: r.target_time.as_nanos() as u64,
                n_alphas: n_alphas as u8,
                alphas,
            },
        );
    }
}

/// Sentinel: no single job is decoding right now.
const CURRENT_NONE: usize = usize::MAX;
/// Sentinel: the whole group is decoding in lockstep — a panic has no
/// single owner, so every unreplied job takes the requeue-once path.
pub(crate) const CURRENT_GROUP: usize = usize::MAX - 1;

/// Panic-survivable holder for one scheduled batch. Jobs live in fixed
/// slots until the instant they are answered, and the executor marks
/// which slot (or the whole group) is decoding — so when a panic
/// unwinds through [`execute_batch`], the replica's supervisor can
/// still reach every unreplied job and give each a typed terminal
/// outcome. No reply channel is ever dropped on the floor; no client
/// waits out the engine timeout because a replica crashed.
pub(crate) struct GroupRun {
    slots: Mutex<Vec<Option<QueuedJob>>>,
    current: AtomicUsize,
    len: usize,
}

impl GroupRun {
    /// Wrap one scheduled batch for supervised execution.
    pub(crate) fn new(jobs: Vec<QueuedJob>) -> GroupRun {
        let len = jobs.len();
        GroupRun {
            slots: Mutex::new(jobs.into_iter().map(Some).collect()),
            current: AtomicUsize::new(CURRENT_NONE),
            len,
        }
    }

    /// Slot count (taken slots included).
    fn len(&self) -> usize {
        self.len
    }

    /// Mark slot `i` (or [`CURRENT_GROUP`]) as the decode in flight.
    /// Never hold the slots lock while marked — the decode may panic.
    fn mark(&self, i: usize) {
        self.current.store(i, Ordering::Relaxed);
    }

    /// Clear the in-flight mark after a decode returns.
    fn clear_mark(&self) {
        self.current.store(CURRENT_NONE, Ordering::Relaxed);
    }

    /// Borrow the job in slot `i` for a short, non-panicking read
    /// (request validation, seed extraction). `None` if already taken.
    fn with<R>(&self, i: usize, f: impl FnOnce(&QueuedJob) -> R) -> Option<R> {
        lock_ignore_poison(&self.slots)[i].as_ref().map(f)
    }

    /// Remove the job in slot `i` — the caller is about to answer it.
    fn take(&self, i: usize) -> Option<QueuedJob> {
        lock_ignore_poison(&self.slots)[i].take()
    }

    /// Take slot `i` and send it `r` (no-op if already answered).
    fn reply(&self, i: usize, r: Result<ForecastResponse, ServeError>) {
        if let Some(qj) = self.take(i) {
            let _ = qj.job.reply.send(r);
        }
    }

    /// Answer every job still held after a panic unwound the executor.
    /// The job that was decoding — when one is identifiable — gets a
    /// typed [`ServeError::ReplicaFailure`] (it poisoned the replica;
    /// retrying it would crash the next one too). Every other job is
    /// requeued exactly once; a second strike fails it the same way, so
    /// one deterministic poison job can take down at most two decode
    /// attempts, never the fleet.
    pub(crate) fn recover_after_panic(
        &self,
        key: GroupKey,
        queue: &AdmissionQueue,
        shared: &SchedShared,
        panic_msg: &str,
        replica: usize,
    ) {
        let current = self.current.load(Ordering::Relaxed);
        let taken: Vec<(usize, QueuedJob)> = {
            let mut slots = lock_ignore_poison(&self.slots);
            slots
                .iter_mut()
                .enumerate()
                .filter_map(|(i, s)| s.take().map(|qj| (i, qj)))
                .collect()
        };
        for (i, qj) in taken {
            if i == current || qj.requeued {
                shared.metrics.inc("replica_failures", 1);
                shared.metrics.errors_total.fetch_add(1, Ordering::Relaxed);
                if let Some(t) = &shared.trace {
                    t.record(
                        qj.job.req.request_id.unwrap_or(0),
                        EventKind::ReplicaFailed { replica: replica as u32 },
                    );
                }
                let _ = qj.job.reply.send(Err(ServeError::ReplicaFailure(format!(
                    "replica panicked during decode: {panic_msg}"
                ))));
            } else {
                queue.requeue(key, qj);
            }
        }
    }
}

/// Push the controller's current state to the gauge set (shared by the
/// lockstep, tree, and breaker-fallback paths). With tracing enabled,
/// operating-point movement relative to the previously published gauges
/// also lands in the flight recorder as control-plane `retune`/`breaker`
/// events (best-effort: two replicas publishing concurrently may both
/// record the same transition — duplicates in a debug ring beat a lock
/// around every publish).
fn publish_controller(shared: &SchedShared, s: &ControllerState) {
    let metrics = &shared.metrics;
    if let Some(t) = &shared.trace {
        let moved = metrics.gauge("controller_gamma") != Some(s.gamma as f64)
            || metrics.gauge("controller_k") != Some(s.k as f64);
        if moved {
            t.record(0, EventKind::Retune {
                gamma: s.gamma.min(u8::MAX as usize) as u8,
                k: s.k.min(u8::MAX as usize) as u8,
            });
        }
        let breaker = s.breaker.gauge();
        if metrics.gauge("breaker_state") != Some(breaker) {
            t.record(0, EventKind::Breaker { state: breaker as u8 });
        }
    }
    metrics.set_gauge("controller_gamma", s.gamma as f64);
    metrics.set_gauge("controller_k", s.k as f64);
    metrics.set_gauge("controller_alpha_hat", s.alpha_hat);
    metrics.set_gauge("controller_c", s.c);
    metrics.set_gauge("controller_rounds", s.rounds as f64);
    metrics.set_gauge("controller_gamma_changes", s.gamma_changes as f64);
    metrics.set_gauge("controller_k_changes", s.k_changes as f64);
    metrics.set_gauge("breaker_state", s.breaker.gauge());
    metrics.set_gauge("breaker_trips", s.breaker_trips as f64);
}

/// Fold a decode failure into the fault ledger. The engine's numeric
/// guards tag their errors with a "non-finite" marker (see
/// `specdec::engine`'s `ensure_finite`); those count as numeric faults
/// and are reported to the speculation circuit breaker, which may trip
/// decode to the pure-AR fallback.
fn note_decode_failure(
    shared: &SchedShared,
    controller: Option<&Mutex<GammaController>>,
    e: &anyhow::Error,
) {
    if !format!("{e:#}").contains("non-finite") {
        return;
    }
    shared.metrics.inc("numeric_faults", 1);
    if let Some(ctrl) = controller {
        let mut c = lock_ignore_poison(ctrl);
        c.note_numeric_fault();
        let s = c.state();
        drop(c);
        publish_controller(shared, &s);
    }
}

/// Execute one scheduled batch on a replica's stacks: a lockstep
/// speculative decode for an SD group, per-job AR decodes for singles.
#[allow(clippy::too_many_arguments)]
pub(crate) fn execute_batch(
    cfg: &ServeConfig,
    shape: ModelShape,
    target: &dyn Backend,
    draft: &dyn Backend,
    key: GroupKey,
    run: &GroupRun,
    shared: &SchedShared,
    replica: usize,
) {
    match key {
        GroupKey::Single => {
            for i in 0..run.len() {
                run_single(cfg, shape, target, draft, run, i, shared, replica);
            }
        }
        GroupKey::Sd { gamma, k, sigma_bits, cache, adaptive, kind } => {
            let ctrl = if adaptive { shared.controller.as_deref() } else { None };
            // γ = 0 group keys exist only while the speculation circuit
            // breaker is open (static configs validate γ ≥ 1): serve
            // pure-AR on the target and tick the breaker's cool-down.
            if gamma == 0 {
                run_ar_fallback_group(cfg, shape, target, run, kind, shared, ctrl, replica);
                return;
            }
            let mut spec = cfg.spec_config();
            spec.gamma = gamma;
            spec.k = k;
            spec.policy.sigma = f64::from_bits(sigma_bits);
            spec.cache = if cache { CacheMode::On } else { CacheMode::Off };
            spec.draft.kind = kind;
            spec.adaptive = if adaptive { Some(cfg.adaptive_cfg) } else { None };
            if k > 1 {
                run_tree_group(cfg, shape, target, draft, run, &spec, shared, ctrl, replica);
            } else {
                if let Some(a) = spec.adaptive.as_mut() {
                    // The lockstep batched engine spends the batch axis
                    // on sequences, not branches: it only runs k_max = 1
                    // controllers. The fleet controller (fed after the
                    // group) still retunes (γ × k) jointly — a k > 1
                    // recommendation routes *future* admissions to the
                    // tree path above.
                    a.k_max = 1;
                }
                run_sd_group(cfg, shape, target, draft, run, &spec, shared, ctrl, replica);
            }
        }
    }
}

#[allow(clippy::too_many_arguments)]
fn run_sd_group(
    cfg: &ServeConfig,
    shape: ModelShape,
    target: &dyn Backend,
    draft: &dyn Backend,
    run: &GroupRun,
    spec: &SpecConfig,
    shared: &SchedShared,
    controller: Option<&Mutex<GammaController>>,
    replica: usize,
) {
    let metrics = &shared.metrics;
    // Validate all; drop invalid with error replies. Surviving jobs stay
    // in their holder slots (tracked by index) until answered.
    let mut ok: Vec<(usize, Vec<f32>, usize, usize, u64)> = Vec::new();
    for i in 0..run.len() {
        let Some((prep_res, seed)) = run.with(i, |qj| {
            (prep(&qj.job.req, shape, spec.gamma), qj.job.req.seed.unwrap_or(cfg.seed))
        }) else {
            continue;
        };
        match prep_res {
            Ok((hist, n, hz)) => ok.push((i, hist, n, hz, seed)),
            Err(e) => {
                metrics.errors_total.fetch_add(1, Ordering::Relaxed);
                run.reply(i, Err(ServeError::Invalid(e)));
            }
        }
    }
    if ok.is_empty() {
        return;
    }
    let tasks: Vec<(&[f32], usize, usize)> =
        ok.iter().map(|(_, h, n, hz, _)| (h.as_slice(), *n, *hz)).collect();
    // One decode seed per request: the response becomes a pure function
    // of the request, independent of batching, replica count, and
    // arrival order (the scheduler's determinism contract).
    let seeds: Vec<u64> = ok.iter().map(|(_, _, _, _, s)| *s).collect();
    // Build the group's draft source explicitly so learned state can be
    // threaded across groups and replicas: seed fresh sources with the
    // fleet's current merged head, merge the export back after.
    let mut source = match make_batch_source(&spec.draft, draft) {
        Ok(s) => s,
        Err(e) => {
            for (i, ..) in ok {
                metrics.errors_total.fetch_add(1, Ordering::Relaxed);
                run.reply(i, Err(ServeError::Internal(format!("draft source failed: {e:#}"))));
            }
            return;
        }
    };
    if let Some(h) = shared.head_for(spec.draft.kind) {
        if let Err(e) = source.import_head(&h) {
            log::warn!("stale draft head discarded: {e:#}");
            shared.discard_head(spec.draft.kind);
        }
    }
    let t0 = Instant::now();
    // The group decodes in lockstep: a panic in here has no single
    // identifiable owner, so the group sentinel sends every unreplied
    // job down the supervisor's requeue-once path.
    run.mark(CURRENT_GROUP);
    let decoded = match &shared.trace {
        Some(sink) => {
            // `ok` is in task order, which is exactly the sequence order
            // the batched engine reports rounds under.
            let rids: Vec<u64> = ok
                .iter()
                .map(|(i, ..)| {
                    run.with(*i, |qj| qj.job.req.request_id.unwrap_or(0)).unwrap_or(0)
                })
                .collect();
            let obs = Arc::new(TraceRoundObserver::new(Arc::clone(sink), rids, spec.draft.kind));
            with_round_observer(obs, || {
                sd_generate_stream_seeded(target, source.as_mut(), &tasks, &seeds, usize::MAX, spec)
            })
        }
        None => sd_generate_stream_seeded(target, source.as_mut(), &tasks, &seeds, usize::MAX, spec),
    };
    run.clear_mark();
    match decoded {
        Ok(outs) => {
            if let Some(h) = source.export_head() {
                shared.merge_head(spec.draft.kind, h);
            }
            let batch_wall = t0.elapsed();
            // Feed the finished group back into the server's long-lived
            // controller: every round (including rejected ones) updates
            // α̂/c, and the next batch's adaptive jobs will key on the
            // possibly-retuned γ — whichever replica they land on.
            if let Some(ctrl) = controller {
                let mut c = lock_ignore_poison(ctrl);
                for out in &outs {
                    for r in &out.rounds {
                        c.observe_round(r);
                    }
                }
                let s = c.state();
                drop(c);
                publish_controller(shared, &s);
            }
            // Per-draft-source serving aggregates (see PR 4): EWMA α̂/c
            // per kind plus monotone decode/update counts.
            let kind = spec.draft.kind.as_str();
            let mut agg = DecodeStats::default();
            for out in &outs {
                agg.merge(&out.stats);
            }
            metrics.inc(&format!("draft_{kind}_decodes"), outs.len() as u64);
            metrics.inc(&format!("draft_{kind}_updates"), agg.draft_updates as u64);
            metrics.ewma_gauge(&format!("draft_{kind}_alpha_hat"), agg.alpha_hat(), 0.8);
            metrics.ewma_gauge(&format!("draft_{kind}_c"), agg.cost_ratio(), 0.8);
            for ((i, _, _, _, seed), out) in ok.into_iter().zip(outs) {
                let Some(qj) = run.take(i) else { continue };
                let latency = qj.job.enqueued.elapsed();
                observe_served(shared, &qj, latency);
                metrics.observe("decode_latency", batch_wall);
                metrics.observe("draft_compute", out.stats.draft_time);
                metrics.observe("verify_compute", out.stats.target_time);
                metrics
                    .patches_total
                    .fetch_add(out.patches.len() as u64 / shape.patch as u64, Ordering::Relaxed);
                let alpha = out.stats.alpha_hat();
                if alpha.is_finite() {
                    shared.monitor.record(alpha);
                }
                let resp = ForecastResponse {
                    forecast: out.patches,
                    mode: "sd".into(),
                    draft: spec.draft.kind.as_str().into(),
                    request_id: qj.job.req.request_id.unwrap_or(0),
                    priority: qj.priority.as_str().into(),
                    replica,
                    seed,
                    latency_ms: latency.as_secs_f64() * 1e3,
                    alpha_hat: alpha,
                    mean_block_len: out.stats.mean_block_len(),
                    rounds: out.stats.rounds,
                    draft_calls: out.stats.draft_calls,
                    target_calls: out.stats.target_calls,
                };
                let _ = qj.job.reply.send(Ok(resp));
            }
        }
        Err(e) => {
            note_decode_failure(shared, controller, &e);
            for (i, ..) in ok {
                metrics.errors_total.fetch_add(1, Ordering::Relaxed);
                run.reply(i, Err(ServeError::Internal(format!("decode failed: {e:#}"))));
            }
        }
    }
}

/// Serve a γ = 0 SD group as pure-AR decodes on the target model — the
/// open circuit breaker's fallback path. Forecast quality is the
/// target model's own (nothing speculative to get wrong); every served
/// horizon ticks the breaker's cool-down so it can reach half-open and
/// probe its way back to speculation.
#[allow(clippy::too_many_arguments)]
fn run_ar_fallback_group(
    cfg: &ServeConfig,
    shape: ModelShape,
    target: &dyn Backend,
    run: &GroupRun,
    kind: DraftKind,
    shared: &SchedShared,
    controller: Option<&Mutex<GammaController>>,
    replica: usize,
) {
    let metrics = &shared.metrics;
    let mut served = 0u64;
    let mut rounds_total = 0usize;
    for i in 0..run.len() {
        let Some((cache_req, prep_res, seed)) = run.with(i, |qj| {
            (qj.job.req.cache, prep(&qj.job.req, shape, 1), qj.job.req.seed.unwrap_or(cfg.seed))
        }) else {
            continue;
        };
        let (hist, n_hist, horizon) = match prep_res {
            Ok(p) => p,
            Err(e) => {
                metrics.errors_total.fetch_add(1, Ordering::Relaxed);
                run.reply(i, Err(ServeError::Invalid(e)));
                continue;
            }
        };
        let cache = if cache_req.unwrap_or(cfg.cache) { CacheMode::On } else { CacheMode::Off };
        run.mark(i);
        let decoded = ar_decode_with(target, &hist, n_hist, horizon, cache);
        run.clear_mark();
        match decoded {
            Ok((pred, _wall, calls)) => {
                served += 1;
                rounds_total += horizon;
                let Some(qj) = run.take(i) else { continue };
                let latency = qj.job.enqueued.elapsed();
                observe_served(shared, &qj, latency);
                metrics.patches_total.fetch_add(horizon as u64, Ordering::Relaxed);
                let resp = ForecastResponse {
                    forecast: pred,
                    mode: "sd".into(),
                    draft: kind.as_str().into(),
                    request_id: qj.job.req.request_id.unwrap_or(0),
                    priority: qj.priority.as_str().into(),
                    replica,
                    seed,
                    latency_ms: latency.as_secs_f64() * 1e3,
                    alpha_hat: f64::NAN,
                    mean_block_len: f64::NAN,
                    rounds: horizon,
                    draft_calls: 0,
                    target_calls: calls,
                };
                let _ = qj.job.reply.send(Ok(resp));
            }
            Err(e) => {
                note_decode_failure(shared, controller, &e);
                metrics.errors_total.fetch_add(1, Ordering::Relaxed);
                run.reply(i, Err(ServeError::Internal(format!("decode failed: {e:#}"))));
            }
        }
    }
    metrics.inc("breaker_fallback_decodes", served);
    if let Some(ctrl) = controller {
        let mut c = lock_ignore_poison(ctrl);
        c.tick_fallback(rounds_total);
        let s = c.state();
        drop(c);
        publish_controller(shared, &s);
    }
}

/// Execute a k > 1 group as per-job tree decodes. Tree speculation
/// spends the target's batch axis on candidate branches, so jobs in the
/// group run sequentially through [`sd_generate_tree_from`] — each with
/// its own seed and draft source, keeping the response a pure function
/// of the request exactly like the lockstep path. Learned draft heads
/// thread through the fleet snapshot the same way, and adaptive groups
/// feed every round back into the long-lived (γ × k) controller.
#[allow(clippy::too_many_arguments)]
fn run_tree_group(
    cfg: &ServeConfig,
    shape: ModelShape,
    target: &dyn Backend,
    draft: &dyn Backend,
    run: &GroupRun,
    spec: &SpecConfig,
    shared: &SchedShared,
    controller: Option<&Mutex<GammaController>>,
    replica: usize,
) {
    let metrics = &shared.metrics;
    metrics.set_gauge("tree_k", spec.k as f64);
    let kind = spec.draft.kind.as_str();
    for i in 0..run.len() {
        let Some((prep_res, seed)) = run.with(i, |qj| {
            (prep(&qj.job.req, shape, spec.gamma), qj.job.req.seed.unwrap_or(cfg.seed))
        }) else {
            continue;
        };
        let (hist, n_hist, horizon) = match prep_res {
            Ok(p) => p,
            Err(e) => {
                metrics.errors_total.fetch_add(1, Ordering::Relaxed);
                run.reply(i, Err(ServeError::Invalid(e)));
                continue;
            }
        };
        let mut source = match make_source(&spec.draft, draft) {
            Ok(s) => s,
            Err(e) => {
                metrics.errors_total.fetch_add(1, Ordering::Relaxed);
                run.reply(i, Err(ServeError::Internal(format!("draft source failed: {e:#}"))));
                continue;
            }
        };
        if let Some(h) = shared.head_for(spec.draft.kind) {
            if let Err(e) = source.import_head(&h) {
                log::warn!("stale draft head discarded: {e:#}");
                shared.discard_head(spec.draft.kind);
            }
        }
        let mut job_spec = *spec;
        job_spec.seed = seed;
        let t0 = Instant::now();
        // Tree decodes are per-job: a panic mid-decode poisons exactly
        // this slot (the supervisor fails it typed, requeues the rest).
        run.mark(i);
        let decoded = match &shared.trace {
            Some(sink) => {
                let rid = run.with(i, |qj| qj.job.req.request_id.unwrap_or(0)).unwrap_or(0);
                let obs = Arc::new(TraceRoundObserver::new(
                    Arc::clone(sink),
                    vec![rid],
                    spec.draft.kind,
                ));
                with_round_observer(obs, || {
                    sd_generate_tree_from(target, source.as_mut(), &hist, n_hist, horizon, &job_spec)
                })
            }
            None => sd_generate_tree_from(target, source.as_mut(), &hist, n_hist, horizon, &job_spec),
        };
        run.clear_mark();
        match decoded {
            Ok(out) => {
                let Some(qj) = run.take(i) else { continue };
                if let Some(h) = source.export_head() {
                    shared.merge_head(spec.draft.kind, h);
                }
                let wall = t0.elapsed();
                metrics.inc("tree_decodes", 1);
                metrics.inc("tree_rounds", out.stats.rounds as u64);
                metrics.inc("tree_branches_verified", out.stats.branches_verified as u64);
                // Winner-depth histogram: how deep the committed branch
                // ran, per tree round (capped — the tail folds into the
                // last bucket).
                for r in &out.rounds {
                    if r.branches > 1 {
                        metrics.inc(&format!("tree_winner_depth_{}", r.accepted.min(8)), 1);
                    }
                }
                if let Some(ctrl) = controller {
                    let mut c = lock_ignore_poison(ctrl);
                    for r in &out.rounds {
                        c.observe_round(r);
                    }
                    let s = c.state();
                    drop(c);
                    publish_controller(shared, &s);
                }
                metrics.inc(&format!("draft_{kind}_decodes"), 1);
                metrics.inc(&format!("draft_{kind}_updates"), out.stats.draft_updates as u64);
                metrics.ewma_gauge(&format!("draft_{kind}_alpha_hat"), out.stats.alpha_hat(), 0.8);
                metrics.ewma_gauge(&format!("draft_{kind}_c"), out.stats.cost_ratio(), 0.8);
                let latency = qj.job.enqueued.elapsed();
                observe_served(shared, &qj, latency);
                metrics.observe("decode_latency", wall);
                metrics.observe("draft_compute", out.stats.draft_time);
                metrics.observe("verify_compute", out.stats.target_time);
                metrics
                    .patches_total
                    .fetch_add(out.patches.len() as u64 / shape.patch as u64, Ordering::Relaxed);
                let alpha = out.stats.alpha_hat();
                if alpha.is_finite() {
                    shared.monitor.record(alpha);
                }
                let resp = ForecastResponse {
                    forecast: out.patches,
                    mode: "sd".into(),
                    draft: kind.into(),
                    request_id: qj.job.req.request_id.unwrap_or(0),
                    priority: qj.priority.as_str().into(),
                    replica,
                    seed: job_spec.seed,
                    latency_ms: latency.as_secs_f64() * 1e3,
                    alpha_hat: alpha,
                    mean_block_len: out.stats.mean_block_len(),
                    rounds: out.stats.rounds,
                    draft_calls: out.stats.draft_calls,
                    target_calls: out.stats.target_calls,
                };
                let _ = qj.job.reply.send(Ok(resp));
            }
            Err(e) => {
                note_decode_failure(shared, controller, &e);
                metrics.errors_total.fetch_add(1, Ordering::Relaxed);
                run.reply(i, Err(ServeError::Internal(format!("tree decode failed: {e:#}"))));
            }
        }
    }
}

fn run_single(
    cfg: &ServeConfig,
    shape: ModelShape,
    target: &dyn Backend,
    draft: &dyn Backend,
    run: &GroupRun,
    i: usize,
    shared: &SchedShared,
    replica: usize,
) {
    let metrics = &shared.metrics;
    let Some((mode, cache_req, prep_res, seed)) = run.with(i, |qj| {
        (
            qj.job.req.mode.clone(),
            qj.job.req.cache,
            prep(&qj.job.req, shape, 1),
            qj.job.req.seed.unwrap_or(cfg.seed),
        )
    }) else {
        return;
    };
    let (hist, n_hist, horizon) = match prep_res {
        Ok(p) => p,
        Err(e) => {
            metrics.errors_total.fetch_add(1, Ordering::Relaxed);
            run.reply(i, Err(ServeError::Invalid(e)));
            return;
        }
    };
    let model: &dyn Backend = match mode {
        Mode::DraftOnly => draft,
        _ => target,
    };
    let cache = if cache_req.unwrap_or(cfg.cache) { CacheMode::On } else { CacheMode::Off };
    run.mark(i);
    let decoded = ar_decode_with(model, &hist, n_hist, horizon, cache);
    run.clear_mark();
    match decoded {
        Ok((pred, _wall, calls)) => {
            let Some(qj) = run.take(i) else { return };
            let latency = qj.job.enqueued.elapsed();
            observe_served(shared, &qj, latency);
            metrics.patches_total.fetch_add(horizon as u64, Ordering::Relaxed);
            let draft_only = mode == Mode::DraftOnly;
            let resp = ForecastResponse {
                forecast: pred,
                mode: if draft_only { "draft" } else { "baseline" }.into(),
                // AR modes draft nothing; the field names the proposal
                // source of SD decodes only.
                draft: String::new(),
                request_id: qj.job.req.request_id.unwrap_or(0),
                priority: qj.priority.as_str().into(),
                replica,
                seed,
                latency_ms: latency.as_secs_f64() * 1e3,
                alpha_hat: f64::NAN,
                mean_block_len: f64::NAN,
                rounds: horizon,
                draft_calls: if draft_only { calls } else { 0 },
                target_calls: if draft_only { 0 } else { calls },
            };
            let _ = qj.job.reply.send(Ok(resp));
        }
        Err(e) => {
            metrics.errors_total.fetch_add(1, Ordering::Relaxed);
            run.reply(i, Err(ServeError::Internal(format!("{e:#}"))));
        }
    }
}
