//! The serving front end: HTTP routes over the serving scheduler.
//!
//! Routes:
//! * `POST /forecast` — forecast request (see [`protocol`]). Errors are
//!   typed: 429 + `Retry-After` when shed by the bounded admission
//!   queue, 504 when a deadline expired before decoding, 400 for
//!   invalid requests, 500 for decode failures. Every reply — success
//!   or error — carries the request's id in `X-Request-Id` (and in the
//!   body); clients may supply their own via the JSON `"request_id"`
//!   field or the `X-Request-Id` header (the body wins).
//! * `GET  /healthz`  — **readiness** probe: HTTP 200 `"ready": true`
//!   normally, HTTP 503 `"ready": false` while the admission queue is
//!   saturated or the server is draining ahead of shutdown (external
//!   load balancers drain a hot replica on this).
//! * `GET  /metrics`  — Prometheus-style metrics text.
//! * `GET  /stats`    — JSON snapshot (acceptance monitor, latency
//!   quantiles, per-draft-source aggregates, the adaptive-controller
//!   state, the `"tree"` block — k > 1 decode counts and the
//!   winner-depth histogram — the `"scheduler"` block: policy,
//!   replicas, queue depth/cap, shed/expired/steal counts, per-priority
//!   latency and SLO attainment — and the `"faults"` block: injected
//!   chaos counters, replica restarts, requeues, numeric faults, and
//!   the speculation circuit breaker's state — plus the `"trace"`
//!   block: flight-recorder enablement, capacity, and exact
//!   recorded/dropped counts).
//! * `GET  /debug/trace` — the flight recorder's live ring as Chrome
//!   trace-event JSON (load in `chrome://tracing` / Perfetto). 404
//!   unless the server started with `--trace-capacity > 0`.
//! * `GET  /debug/requests/<id>` — one request's recorded timeline by
//!   id (16-hex, as echoed in `X-Request-Id`).
//!
//! Registry + swap routes (see [`crate::registry`]):
//! * `GET  /v1/models` — tags in the server's registry.
//! * `GET/PUT /v1/models/<name>/<version>` — manifest by tag
//!   (`/v1/models/sha256/<hex>` addresses by content; `sha256` is a
//!   reserved model name). PUT follows the blobs-first push protocol:
//!   a manifest referencing absent blobs is a 404.
//! * `GET/PUT /v1/blobs/<sha256>` — raw weight blobs
//!   (`application/octet-stream`). PUT re-hashes the received bytes
//!   against the path digest — a corrupt upload is a typed 422
//!   (`digest_mismatch`), never a poisoned cache entry.
//! * `POST /admin/swap` — body `{"model": "<name>:<version>"}` (or
//!   `"sha256:<hex>"`): verify + load the pair, then live-swap the
//!   replica pool with zero dropped requests. The reply reports the new
//!   digest/generation and how many replicas rebound inside the
//!   barrier. `/healthz` and `/stats` carry the serving model identity.
//!
//! The router validates and parses on HTTP worker threads; all model
//! work happens on the engine replica threads behind the scheduler
//! ([`sched`]). Request bodies are capped at `ServeConfig::
//! max_body_bytes` (typed 413 past it — registry pushes are the
//! legitimate large-body traffic).

mod batcher;
pub mod protocol;
pub mod sched;

pub use batcher::{start_engine, start_engine_with_builder, BatcherHandle, Job, SwapReport};
pub use protocol::{ForecastRequest, ForecastResponse, Mode, Priority, ServeError};
pub use sched::{ModelShape, ReplicaBuilder, ReplicaStacks};

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

use anyhow::Result;

use crate::config::ServeConfig;
use crate::http::{HttpServer, Request, Response};
use crate::metrics::{AcceptanceMonitor, Metrics};
use crate::util::json::Json;

/// A running forecast service: HTTP front end + scheduler + replicas.
pub struct Server {
    /// The bound HTTP listener (owns the accept loop).
    pub http: HttpServer,
    /// Handle for submitting jobs and reading metrics/scheduler state.
    pub handle: BatcherHandle,
    stop: Arc<AtomicBool>,
    replica_threads: Vec<std::thread::JoinHandle<()>>,
}

impl Server {
    /// Start the scheduler + HTTP front end from the artifacts manifest;
    /// returns once every replica is ready.
    pub fn start(cfg: ServeConfig) -> Result<Server> {
        cfg.validate()?;
        Self::start_inner(cfg, None)
    }

    /// [`Server::start`] over an injected replica builder and model
    /// shape — the artifact-free entry for tests and benches (synthetic
    /// in-memory models, full HTTP + scheduler stack).
    pub fn start_with_builder(
        cfg: ServeConfig,
        shape: ModelShape,
        builder: ReplicaBuilder,
    ) -> Result<Server> {
        cfg.validate()?;
        Self::start_inner(cfg, Some((shape, builder)))
    }

    fn start_inner(
        cfg: ServeConfig,
        injected: Option<(ModelShape, ReplicaBuilder)>,
    ) -> Result<Server> {
        let metrics = Arc::new(Metrics::new());
        // Window of 256 recent per-request acceptance means; alert at 0.8
        // per the paper's §7 conservative-threshold guidance.
        let monitor = Arc::new(AcceptanceMonitor::new(256, 0.8));
        let stop = Arc::new(AtomicBool::new(false));
        let (handle, replica_threads) = match injected {
            None => start_engine(cfg.clone(), metrics, monitor, stop.clone())?,
            Some((shape, builder)) => start_engine_with_builder(
                cfg.clone(),
                shape,
                builder,
                metrics,
                monitor,
                stop.clone(),
            )?,
        };

        let h = handle.clone();
        let http = HttpServer::start_with_limits(
            &cfg.bind,
            cfg.http_workers,
            Arc::new(move |req: &Request| route(req, &h)),
            std::time::Duration::from_secs(30),
            std::time::Duration::from_secs(30),
            cfg.max_body_bytes,
        )?;
        log::info!("serving on {}", http.addr);
        Ok(Server { http, handle, stop, replica_threads })
    }

    /// The bound listen address (useful with port 0).
    pub fn addr(&self) -> std::net::SocketAddr {
        self.http.addr
    }

    /// Graceful shutdown: stop admitting (new requests get a typed 503
    /// `"draining"` while `/healthz` reports not-ready), let replicas
    /// finish what is already queued — up to the `drain_ms` budget —
    /// then hard-stop. Returns `true` when the queue fully drained
    /// inside the budget, `false` when jobs were still queued at the
    /// deadline (they are failed by the hard shutdown, never hung).
    pub fn drain(&mut self, budget: std::time::Duration) -> bool {
        self.handle.begin_drain();
        let deadline = std::time::Instant::now() + budget;
        let drained = loop {
            if self.handle.queue_depth() == 0 {
                break true;
            }
            if std::time::Instant::now() >= deadline {
                break false;
            }
            std::thread::sleep(std::time::Duration::from_millis(5));
        };
        self.shutdown();
        drained
    }

    /// Stop accepting, drain the scheduler, and join everything.
    pub fn shutdown(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        self.http.shutdown();
        self.handle.shutdown();
        for t in self.replica_threads.drain(..) {
            let _ = t.join();
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.shutdown();
    }
}

fn route(req: &Request, handle: &BatcherHandle) -> Response {
    match (req.method.as_str(), req.path.as_str()) {
        ("GET", "/healthz") => {
            // Readiness, not just liveness: a saturated admission queue
            // or an in-progress drain means this replica should stop
            // receiving traffic.
            let draining = handle.draining();
            let ready = handle.ready() && !draining;
            let status = if draining {
                "draining"
            } else if ready {
                "ok"
            } else {
                "saturated"
            };
            let body = Json::obj(vec![
                ("status", Json::from(status)),
                ("ready", Json::from(ready)),
                ("draining", Json::from(draining)),
                ("version", Json::from(crate::VERSION)),
                ("queue_depth", Json::from(handle.queue_depth())),
                ("queue_cap", Json::from(handle.queue_cap())),
                ("model_digest", Json::from(handle.model_digest())),
                ("model_generation", Json::from(handle.model_generation() as usize)),
            ])
            .to_string();
            Response::json(if ready { 200 } else { 503 }, body)
        }
        ("GET", "/metrics") => Response::text(200, &handle.metrics.render()),
        ("GET", "/stats") => {
            let m = &handle.metrics;
            let mon = &handle.monitor;
            // Live adaptive-controller snapshot (null when adaptation is
            // off): the serving-side view of specdec::ControllerState.
            let mut breaker_state = None;
            let controller = match &handle.controller {
                Some(ctrl) => {
                    let s = ctrl.lock().unwrap_or_else(|e| e.into_inner()).state();
                    breaker_state = Some((s.breaker, s.breaker_trips));
                    Json::obj(vec![
                        ("draft", Json::from(s.draft)),
                        ("gamma", Json::from(s.gamma)),
                        ("sigma", finite_or_null(s.sigma)),
                        ("alpha_hat", finite_or_null(s.alpha_hat)),
                        ("c", finite_or_null(s.c)),
                        ("rounds", Json::from(s.rounds)),
                        ("proposals", Json::from(s.proposals)),
                        ("gamma_changes", Json::from(s.gamma_changes)),
                        ("sigma_changes", Json::from(s.sigma_changes)),
                        ("k", Json::from(s.k)),
                        ("k_changes", Json::from(s.k_changes)),
                    ])
                }
                None => Json::Null,
            };
            // Per-draft-source aggregates: one entry per source kind that
            // has actually served decodes.
            let mut sources = Vec::new();
            for kind in crate::specdec::DraftKind::all() {
                let k = kind.as_str();
                let decodes = m.counter(&format!("draft_{k}_decodes"));
                if decodes == 0 {
                    continue;
                }
                sources.push((
                    k,
                    Json::obj(vec![
                        ("decodes", Json::from(decodes as usize)),
                        (
                            "alpha_hat",
                            m.gauge(&format!("draft_{k}_alpha_hat"))
                                .map(Json::Num)
                                .unwrap_or(Json::Null),
                        ),
                        (
                            "c",
                            m.gauge(&format!("draft_{k}_c"))
                                .map(Json::Num)
                                .unwrap_or(Json::Null),
                        ),
                        (
                            "updates",
                            Json::from(m.counter(&format!("draft_{k}_updates")) as usize),
                        ),
                    ]),
                ));
            }
            let draft = Json::obj(vec![
                ("default", Json::from(handle.draft.as_str())),
                ("sources", Json::obj(sources)),
            ]);
            // Tree-speculation block: per-job k > 1 decodes served so
            // far. `winner_depth[d]` counts tree rounds whose committed
            // branch ran d patches deep (the last bucket absorbs the
            // tail); all-zero until the first k > 1 request.
            let tree = Json::obj(vec![
                ("decodes", Json::from(m.counter("tree_decodes") as usize)),
                ("rounds", Json::from(m.counter("tree_rounds") as usize)),
                (
                    "branches_verified",
                    Json::from(m.counter("tree_branches_verified") as usize),
                ),
                ("k", m.gauge("tree_k").map(Json::Num).unwrap_or(Json::Null)),
                (
                    "winner_depth",
                    Json::Arr(
                        (0..=8)
                            .map(|d| {
                                Json::from(m.counter(&format!("tree_winner_depth_{d}")) as usize)
                            })
                            .collect(),
                    ),
                ),
            ]);
            // Scheduler block: admission + dispatch + per-priority SLO
            // state (see server::sched).
            let mut priorities = Vec::new();
            for p in Priority::all() {
                let name = p.as_str();
                priorities.push((
                    name,
                    Json::obj(vec![
                        (
                            "latency_p50_ms",
                            Json::Num(m.quantile_ms(&format!("request_latency_{name}"), 0.5)),
                        ),
                        (
                            "latency_p99_ms",
                            Json::Num(m.quantile_ms(&format!("request_latency_{name}"), 0.99)),
                        ),
                        (
                            "slo_attainment",
                            m.gauge(&format!("slo_attainment_{name}"))
                                .map(Json::Num)
                                .unwrap_or(Json::Null),
                        ),
                    ]),
                ));
            }
            // Fault-tolerance ledger: what chaos injected (null unless a
            // plan is armed), what the supervisor absorbed, and where
            // the speculation circuit breaker stands.
            let injection = match &handle.fault {
                Some(plan) => Json::obj(vec![
                    ("injected", Json::from(plan.injected() as usize)),
                    ("panics", Json::from(plan.panics() as usize)),
                    ("stalls", Json::from(plan.stalls() as usize)),
                    ("nans", Json::from(plan.nans() as usize)),
                    ("exhausted", Json::from(plan.exhausted())),
                ]),
                None => Json::Null,
            };
            let faults = Json::obj(vec![
                ("injection", injection),
                ("replica_restarts", Json::from(m.counter("replica_restarts") as usize)),
                ("replica_failures", Json::from(m.counter("replica_failures") as usize)),
                ("requeues", Json::from(m.counter("requeues") as usize)),
                ("numeric_faults", Json::from(m.counter("numeric_faults") as usize)),
                (
                    "breaker",
                    match breaker_state {
                        Some((b, trips)) => Json::obj(vec![
                            ("state", Json::from(b.as_str())),
                            ("trips", Json::from(trips)),
                            (
                                "fallback_decodes",
                                Json::from(m.counter("breaker_fallback_decodes") as usize),
                            ),
                        ]),
                        None => Json::Null,
                    },
                ),
                ("draining", Json::from(handle.draining())),
            ]);
            let scheduler = Json::obj(vec![
                ("policy", Json::from(handle.sched_policy())),
                ("replicas", Json::from(handle.replicas())),
                ("queue_depth", Json::from(handle.queue_depth())),
                ("queue_cap", Json::from(handle.queue_cap())),
                (
                    "shed",
                    Json::from(m.sheds_total.load(Ordering::Relaxed) as usize),
                ),
                (
                    "expired",
                    Json::from(m.expired_total.load(Ordering::Relaxed) as usize),
                ),
                ("steals", Json::from(m.counter("steals") as usize)),
                ("priorities", Json::obj(priorities)),
            ]);
            // Serving-model identity + swap ledger: which weights answer
            // requests right now, and how they got there.
            let model = Json::obj(vec![
                ("digest", Json::from(handle.model_digest())),
                ("label", Json::from(handle.model_label())),
                ("generation", Json::from(handle.model_generation() as usize)),
                ("swaps", Json::from(m.counter("model_swap_total") as usize)),
                ("swap_failures", Json::from(m.counter("model_swap_failed") as usize)),
                ("rebinds", Json::from(m.counter("model_swap_rebinds") as usize)),
                (
                    "rebind_failures",
                    Json::from(m.counter("model_swap_rebind_failures") as usize),
                ),
            ]);
            // Flight-recorder block: same keys in both states, so
            // dashboards key on `trace.enabled` without probing
            // `/debug/trace`.
            let trace = match &handle.trace {
                Some(t) => t.stats_json(),
                None => Json::obj(vec![
                    ("enabled", Json::from(false)),
                    ("capacity", Json::from(0usize)),
                    ("recorded", Json::from(0usize)),
                    ("dropped", Json::from(0usize)),
                ]),
            };
            let j = Json::obj(vec![
                ("requests", Json::from(m.requests_total.load(Ordering::Relaxed) as usize)),
                ("patches", Json::from(m.patches_total.load(Ordering::Relaxed) as usize)),
                ("errors", Json::from(m.errors_total.load(Ordering::Relaxed) as usize)),
                ("alpha_bar_window", finite_or_null(mon.alpha_bar())),
                ("acceptance_degraded", Json::from(mon.degraded())),
                ("adaptive", Json::from(handle.controller.is_some())),
                ("controller", controller),
                ("draft", draft),
                ("tree", tree),
                ("model", model),
                ("scheduler", scheduler),
                ("faults", faults),
                ("trace", trace),
                ("latency_p50_ms", Json::Num(m.quantile_ms("request_latency", 0.5))),
                ("latency_p95_ms", Json::Num(m.quantile_ms("request_latency", 0.95))),
                ("latency_p99_ms", Json::Num(m.quantile_ms("request_latency", 0.99))),
            ]);
            Response::json(200, j.to_string())
        }
        ("POST", "/forecast") => {
            let body = match req.body_str() {
                Ok(s) => s,
                Err(_) => return Response::bad_request("body must be UTF-8"),
            };
            let parsed = match Json::parse(body) {
                Ok(j) => j,
                Err(e) => return Response::bad_request(&format!("bad JSON: {e}")),
            };
            let mut freq = match ForecastRequest::from_json(&parsed) {
                Ok(r) => r,
                Err(e) => return Response::bad_request(&format!("bad request: {e:#}")),
            };
            // `X-Request-Id` is the header spelling of the JSON
            // `"request_id"` field; the body wins when both are set.
            if freq.request_id.is_none() {
                if let Some(h) = req.header("x-request-id") {
                    match crate::trace::parse_request_id(h) {
                        Some(rid) => freq.request_id = Some(rid),
                        None => {
                            return Response::bad_request(
                                "X-Request-Id must be 1-16 hex digits (nonzero)",
                            )
                        }
                    }
                }
            }
            let (rid, result) = handle.forecast_with_id(freq);
            let rid_text = crate::trace::format_request_id(rid);
            match result {
                Ok(resp) => Response::json(200, resp.to_json().to_string())
                    .with_header("X-Request-Id", rid_text),
                Err(e) => {
                    let mut resp = Response::json(
                        e.http_status(),
                        e.to_json_with_request_id(rid).to_string(),
                    )
                    .with_header("X-Request-Id", rid_text);
                    if let ServeError::Shed { retry_after_ms } = &e {
                        // Retry-After is specified in (whole) seconds.
                        let secs = ((retry_after_ms + 999) / 1000).max(1);
                        resp = resp.with_header("Retry-After", secs.to_string());
                    }
                    resp
                }
            }
        }
        ("GET", "/debug/trace") => match &handle.trace {
            Some(t) => Response::json(200, t.chrome_trace_json().to_string()),
            None => trace_disabled(),
        },
        ("GET", p) if p.starts_with("/debug/requests/") => {
            let Some(t) = &handle.trace else { return trace_disabled() };
            let id = &p["/debug/requests/".len()..];
            match crate::trace::parse_request_id(id) {
                Some(rid) => Response::json(200, t.request_timeline_json(rid).to_string()),
                None => Response::bad_request("request id must be 1-16 hex digits (nonzero)"),
            }
        }
        ("POST", "/admin/swap") => {
            let body = match req.body_str() {
                Ok(s) => s,
                Err(_) => return Response::bad_request("body must be UTF-8"),
            };
            let parsed = match Json::parse(body) {
                Ok(j) => j,
                Err(e) => return Response::bad_request(&format!("bad JSON: {e}")),
            };
            let Some(reference) = parsed.get("model").and_then(Json::as_str) else {
                return Response::bad_request(
                    "body must carry {\"model\": \"name:version\"} or \
                     {\"model\": \"sha256:<hex>\"}",
                );
            };
            match handle.swap_model(reference) {
                Ok(r) => Response::json(
                    200,
                    Json::obj(vec![
                        ("status", Json::from(if r.complete { "ok" } else { "partial" })),
                        ("digest", Json::from(r.digest)),
                        ("model", Json::from(r.label)),
                        ("generation", Json::from(r.generation as usize)),
                        ("replicas", Json::from(r.replicas)),
                        ("rebound", Json::from(r.rebound)),
                        ("complete", Json::from(r.complete)),
                        ("duration_ms", Json::from(r.duration_ms as usize)),
                        ("heads", Json::from(r.heads)),
                    ])
                    .to_string(),
                ),
                Err(e) => error_response(&e),
            }
        }
        _ if req.path.starts_with("/v1/") => route_registry(req, handle),
        _ => Response::not_found(),
    }
}

/// Registry API: manifests by tag or content address, raw blobs, and
/// the tag listing. Every externally-supplied name/digest is validated
/// by the registry layer before it touches a path, every write is
/// re-hashed, and every failure is a typed [`ServeError`] body.
fn route_registry(req: &Request, handle: &BatcherHandle) -> Response {
    let registry = match handle.registry() {
        Ok(r) => r,
        Err(e) => return error_response(&e),
    };
    let segs: Vec<&str> = req.path.trim_start_matches('/').split('/').collect();
    match (req.method.as_str(), segs.as_slice()) {
        ("GET", ["v1", "models"]) => match registry.list_tags() {
            Ok(tags) => {
                let body = Json::obj(vec![(
                    "models",
                    Json::Arr(tags.into_iter().map(Json::from).collect()),
                )]);
                Response::json(200, body.to_string())
            }
            Err(e) => error_response(&ServeError::from(e)),
        },
        ("GET", ["v1", "models", head, tail]) => {
            // `sha256` is a reserved model name, so tag and content
            // address share one route shape (see `registry::client`).
            let reference = format!("{head}:{tail}");
            match registry.get_manifest(&reference) {
                // Serve the canonical (sorted-key) form: the bytes a
                // puller re-digests.
                Ok((m, _digest)) => Response::json(200, m.to_json().to_string()),
                Err(e) => error_response(&ServeError::from(e)),
            }
        }
        ("PUT", ["v1", "models", name, version]) => {
            let body = match req.body_str() {
                Ok(s) => s,
                Err(_) => return Response::bad_request("manifest must be UTF-8 JSON"),
            };
            let parsed = match Json::parse(body) {
                Ok(j) => j,
                Err(e) => return Response::bad_request(&format!("bad manifest JSON: {e}")),
            };
            let m = match crate::registry::RegistryManifest::from_json(&parsed) {
                Ok(m) => m,
                Err(e) => return error_response(&ServeError::from(e)),
            };
            if m.name != *name || m.version != *version {
                return Response::bad_request(&format!(
                    "manifest names {}:{} but was PUT to /v1/models/{name}/{version}",
                    m.name, m.version
                ));
            }
            match registry.put_manifest(&m) {
                Ok(digest) => Response::json(
                    201,
                    Json::obj(vec![("digest", Json::from(digest))]).to_string(),
                ),
                Err(e) => error_response(&ServeError::from(e)),
            }
        }
        ("GET", ["v1", "blobs", digest]) => match registry.blobs().read_verified(digest) {
            Ok(bytes) => Response {
                status: 200,
                content_type: "application/octet-stream",
                headers: Vec::new(),
                body: bytes,
            },
            Err(e) => error_response(&ServeError::from(e)),
        },
        ("PUT", ["v1", "blobs", digest]) => {
            // Hash-before-store: a corrupt upload never lands in the
            // cache under a digest it does not match.
            match registry.blobs().put_expected(digest, &req.body) {
                Ok(()) => Response::json(
                    201,
                    Json::obj(vec![("digest", Json::from(*digest))]).to_string(),
                ),
                Err(e) => error_response(&ServeError::from(e)),
            }
        }
        _ => Response::not_found(),
    }
}

/// Serve a typed [`ServeError`] as its canonical JSON body + status.
fn error_response(e: &ServeError) -> Response {
    Response::json(e.http_status(), e.to_json().to_string())
}

/// The `/debug/*` reply on a server running without a flight recorder.
fn trace_disabled() -> Response {
    Response::json(
        404,
        Json::obj(vec![(
            "error",
            Json::from("tracing disabled (start with --trace-capacity N)"),
        )])
        .to_string(),
    )
}

fn finite_or_null(v: f64) -> Json {
    if v.is_finite() {
        Json::Num(v)
    } else {
        Json::Null
    }
}
