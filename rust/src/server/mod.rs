//! The serving front end: HTTP routes over the dynamic batcher.
//!
//! Routes:
//! * `POST /forecast` — forecast request (see [`protocol`]).
//! * `GET  /healthz`  — liveness + version.
//! * `GET  /metrics`  — Prometheus-style metrics text.
//! * `GET  /stats`    — JSON snapshot (acceptance monitor, latency
//!   quantiles, per-draft-source aggregates — α̂, measured c, online
//!   update counts per served source kind — and, when adaptive
//!   speculation is on, the live controller state: current γ, α̂,
//!   measured c, change counts, tagged draft kind).
//!
//! The router validates and parses on HTTP worker threads; all model work
//! happens on the single engine thread behind the batcher (PJRT state is
//! not Send — see `runtime::engine`).

mod batcher;
pub mod protocol;

pub use batcher::{start_engine, BatcherHandle};
pub use protocol::{ForecastRequest, ForecastResponse, Mode};

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

use anyhow::Result;

use crate::config::ServeConfig;
use crate::http::{HttpServer, Request, Response};
use crate::metrics::{AcceptanceMonitor, Metrics};
use crate::util::json::Json;

/// A running forecast service: HTTP front end + engine thread.
pub struct Server {
    /// The bound HTTP listener (owns the accept loop).
    pub http: HttpServer,
    /// Handle for submitting jobs and reading metrics/controller state.
    pub handle: BatcherHandle,
    stop: Arc<AtomicBool>,
    engine_thread: Option<std::thread::JoinHandle<()>>,
}

impl Server {
    /// Start engine + HTTP front end; returns once both are ready.
    pub fn start(cfg: ServeConfig) -> Result<Server> {
        cfg.validate()?;
        let metrics = Arc::new(Metrics::new());
        // Window of 256 recent per-request acceptance means; alert at 0.8
        // per the paper's §7 conservative-threshold guidance.
        let monitor = Arc::new(AcceptanceMonitor::new(256, 0.8));
        let stop = Arc::new(AtomicBool::new(false));
        let (handle, engine_thread) =
            start_engine(cfg.clone(), metrics.clone(), monitor.clone(), stop.clone())?;

        let h = handle.clone();
        let http = HttpServer::start(
            &cfg.bind,
            cfg.http_workers,
            Arc::new(move |req: &Request| route(req, &h)),
        )?;
        log::info!("serving on {}", http.addr);
        Ok(Server { http, handle, stop, engine_thread: Some(engine_thread) })
    }

    /// The bound listen address (useful with port 0).
    pub fn addr(&self) -> std::net::SocketAddr {
        self.http.addr
    }

    /// Stop accepting, drain the engine thread, and join everything.
    pub fn shutdown(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        self.http.shutdown();
        if let Some(t) = self.engine_thread.take() {
            let _ = t.join();
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.shutdown();
    }
}

fn route(req: &Request, handle: &BatcherHandle) -> Response {
    match (req.method.as_str(), req.path.as_str()) {
        ("GET", "/healthz") => Response::json(
            200,
            Json::obj(vec![
                ("status", Json::from("ok")),
                ("version", Json::from(crate::VERSION)),
            ])
            .to_string(),
        ),
        ("GET", "/metrics") => Response::text(200, &handle.metrics.render()),
        ("GET", "/stats") => {
            let m = &handle.metrics;
            let mon = &handle.monitor;
            // Live adaptive-controller snapshot (null when adaptation is
            // off): the serving-side view of specdec::ControllerState.
            let controller = match &handle.controller {
                Some(ctrl) => {
                    let s = ctrl.lock().unwrap().state();
                    Json::obj(vec![
                        ("draft", Json::from(s.draft)),
                        ("gamma", Json::from(s.gamma)),
                        ("sigma", finite_or_null(s.sigma)),
                        ("alpha_hat", finite_or_null(s.alpha_hat)),
                        ("c", finite_or_null(s.c)),
                        ("rounds", Json::from(s.rounds)),
                        ("proposals", Json::from(s.proposals)),
                        ("gamma_changes", Json::from(s.gamma_changes)),
                        ("sigma_changes", Json::from(s.sigma_changes)),
                    ])
                }
                None => Json::Null,
            };
            // Per-draft-source aggregates: one entry per source kind that
            // has actually served decodes (the serving-side view of the
            // pluggable-draft subsystem — α̂, measured c, online-update
            // and decode counts, from the stride_draft_* gauges).
            let mut sources = Vec::new();
            for kind in crate::specdec::DraftKind::all() {
                let k = kind.as_str();
                let decodes = m.counter(&format!("draft_{k}_decodes"));
                if decodes == 0 {
                    continue;
                }
                sources.push((
                    k,
                    Json::obj(vec![
                        ("decodes", Json::from(decodes as usize)),
                        (
                            "alpha_hat",
                            m.gauge(&format!("draft_{k}_alpha_hat"))
                                .map(Json::Num)
                                .unwrap_or(Json::Null),
                        ),
                        (
                            "c",
                            m.gauge(&format!("draft_{k}_c"))
                                .map(Json::Num)
                                .unwrap_or(Json::Null),
                        ),
                        (
                            "updates",
                            Json::from(m.counter(&format!("draft_{k}_updates")) as usize),
                        ),
                    ]),
                ));
            }
            let draft = Json::obj(vec![
                ("default", Json::from(handle.draft.as_str())),
                ("sources", Json::obj(sources)),
            ]);
            let j = Json::obj(vec![
                ("requests", Json::from(m.requests_total.load(Ordering::Relaxed) as usize)),
                ("patches", Json::from(m.patches_total.load(Ordering::Relaxed) as usize)),
                ("errors", Json::from(m.errors_total.load(Ordering::Relaxed) as usize)),
                ("alpha_bar_window", finite_or_null(mon.alpha_bar())),
                ("acceptance_degraded", Json::from(mon.degraded())),
                ("adaptive", Json::from(handle.controller.is_some())),
                ("controller", controller),
                ("draft", draft),
                ("latency_p50_ms", Json::Num(m.quantile_ms("request_latency", 0.5))),
                ("latency_p95_ms", Json::Num(m.quantile_ms("request_latency", 0.95))),
                ("latency_p99_ms", Json::Num(m.quantile_ms("request_latency", 0.99))),
            ]);
            Response::json(200, j.to_string())
        }
        ("POST", "/forecast") => {
            let body = match req.body_str() {
                Ok(s) => s,
                Err(_) => return Response::bad_request("body must be UTF-8"),
            };
            let parsed = match Json::parse(body) {
                Ok(j) => j,
                Err(e) => return Response::bad_request(&format!("bad JSON: {e}")),
            };
            let freq = match ForecastRequest::from_json(&parsed) {
                Ok(r) => r,
                Err(e) => return Response::bad_request(&format!("bad request: {e:#}")),
            };
            match handle.forecast(freq) {
                Ok(resp) => Response::json(200, resp.to_json().to_string()),
                Err(e) => Response::json(
                    500,
                    Json::obj(vec![("error", Json::from(e))]).to_string(),
                ),
            }
        }
        _ => Response::not_found(),
    }
}

fn finite_or_null(v: f64) -> Json {
    if v.is_finite() {
        Json::Num(v)
    } else {
        Json::Null
    }
}
