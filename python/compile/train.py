"""Build-time pretraining + distillation (compile path only, never serving).

The paper's protocol is inference-only over *fixed checkpoints* (§4.1.5):
targets are pretrained foundation models, drafts are down-sampled variants
distilled with a combined KL + MSE objective at temperature tau (§4.1.2).
No public Timer checkpoints are usable here, so ``make artifacts`` performs
the equivalent one-time procedure on the synthetic corpus (DESIGN.md §3):

1. pretrain the target with the Gaussian NLL (== MSE at fixed sigma) on
   teacher-forced windows from all four datasets;
2. distill the 0.25x draft against the frozen target means:
       L = w_kl * ||mu_q - mu_p||^2 / (2 sigma_d^2 tau^2) + w_mse * ||mu_q - x||^2
   which is exactly KL(N(mu_q, s) || N(mu_p, s)) for isotropic heads plus the
   data term.

Optimizer is a hand-rolled Adam (no optax in this environment).
"""

from __future__ import annotations

import dataclasses
import functools
import time
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from . import datagen
from .model import ModelConfig, Params, forward, init_params


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    steps: int = 700
    batch: int = 32
    lr: float = 3e-4
    warmup: int = 50
    windows_per_dataset: int = 2048
    seed: int = 7
    # Distillation knobs (paper §4.1.2).
    distill_steps: int = 500
    distill_tau: float = 2.0
    distill_w_kl: float = 0.7
    distill_w_mse: float = 0.3
    distill_sigma: float = 0.5

    def scaled(self, frac: float) -> "TrainConfig":
        """Down-scaled config for --quick CI runs."""
        return dataclasses.replace(
            self,
            steps=max(20, int(self.steps * frac)),
            distill_steps=max(20, int(self.distill_steps * frac)),
            windows_per_dataset=max(256, int(self.windows_per_dataset * frac)),
        )


def build_corpus(tc: TrainConfig, n_ctx: int, patch: int) -> np.ndarray:
    """Mixed-dataset training windows [n_total, n_ctx+1, patch] (train split)."""
    parts = [
        datagen.sample_windows(spec, patch, n_ctx, tc.windows_per_dataset, seed=tc.seed + j)
        for j, spec in enumerate(datagen.SPECS.values())
    ]
    corpus = np.concatenate(parts, axis=0)
    perm = np.argsort(datagen.uniform01(tc.seed * 31 + 5, np.arange(len(corpus))))
    return corpus[perm]


# ---------------------------------------------------------------------------
# Hand-rolled Adam.
# ---------------------------------------------------------------------------


def adam_init(params: Params):
    zeros = jax.tree_util.tree_map(jnp.zeros_like, params)
    return {"m": zeros, "v": jax.tree_util.tree_map(jnp.zeros_like, params), "t": 0}


def adam_update(params, grads, state, lr, b1=0.9, b2=0.999, eps=1e-8):
    t = state["t"] + 1
    m = jax.tree_util.tree_map(lambda m_, g: b1 * m_ + (1 - b1) * g, state["m"], grads)
    v = jax.tree_util.tree_map(lambda v_, g: b2 * v_ + (1 - b2) * g * g, state["v"], grads)
    mh_scale = 1.0 / (1 - b1**t)
    vh_scale = 1.0 / (1 - b2**t)
    new_params = jax.tree_util.tree_map(
        lambda p_, m_, v_: p_ - lr * (m_ * mh_scale) / (jnp.sqrt(v_ * vh_scale) + eps),
        params, m, v,
    )
    return new_params, {"m": m, "v": v, "t": t}


def _lr_at(step, tc: TrainConfig):
    warm = jnp.minimum(1.0, (step + 1) / tc.warmup)
    decay = 0.5 * (1 + jnp.cos(jnp.pi * jnp.minimum(1.0, step / tc.steps)))
    return tc.lr * warm * (0.1 + 0.9 * decay)


# ---------------------------------------------------------------------------
# Target pretraining.
# ---------------------------------------------------------------------------


def pretrain_target(cfg: ModelConfig, tc: TrainConfig, corpus: np.ndarray,
                    log: Callable[[str], None] = print) -> Params:
    key = jax.random.PRNGKey(tc.seed)
    params = init_params(cfg, key)

    def loss_fn(p, batch):
        inp, tgt = batch[:, :-1], batch[:, 1:]
        mu = forward(p, inp, cfg, use_pallas=False)
        return jnp.mean((mu - tgt) ** 2)

    @jax.jit
    def step_fn(p, opt, batch, step):
        loss, grads = jax.value_and_grad(loss_fn)(p, batch)
        p, opt = adam_update(p, grads, opt, _lr_at(step, tc))
        return p, opt, loss

    opt = adam_init(params)
    n = len(corpus)
    t0 = time.time()
    for step in range(tc.steps):
        lo = (step * tc.batch) % max(1, n - tc.batch)
        batch = jnp.asarray(corpus[lo : lo + tc.batch])
        params, opt, loss = step_fn(params, opt, batch, step)
        if step % 100 == 0 or step == tc.steps - 1:
            log(f"[target {cfg.name}] step {step:4d} loss {float(loss):.4f} "
                f"({time.time() - t0:.0f}s)")
    return params


# ---------------------------------------------------------------------------
# Draft distillation.
# ---------------------------------------------------------------------------


def distill_draft(draft_cfg: ModelConfig, target_cfg: ModelConfig,
                  target_params: Params, tc: TrainConfig, corpus: np.ndarray,
                  log: Callable[[str], None] = print) -> Params:
    key = jax.random.PRNGKey(tc.seed + 1)
    params = init_params(draft_cfg, key)
    kl_scale = tc.distill_w_kl / (2.0 * tc.distill_sigma**2 * tc.distill_tau**2)

    @jax.jit
    def teacher_means(batch):
        return forward(target_params, batch[:, :-1], target_cfg, use_pallas=False)

    def loss_fn(p, batch, mu_t):
        inp, tgt = batch[:, :-1], batch[:, 1:]
        mu_q = forward(p, inp, draft_cfg, use_pallas=False)
        l_kl = jnp.mean(jnp.sum((mu_q - mu_t) ** 2, axis=-1))
        l_mse = jnp.mean((mu_q - tgt) ** 2)
        return kl_scale * l_kl + tc.distill_w_mse * l_mse

    @jax.jit
    def step_fn(p, opt, batch, mu_t, step):
        loss, grads = jax.value_and_grad(loss_fn)(p, batch, mu_t)
        p, opt = adam_update(p, grads, opt, _lr_at(step, tc))
        return p, opt, loss

    opt = adam_init(params)
    n = len(corpus)
    t0 = time.time()
    for step in range(tc.distill_steps):
        lo = (step * tc.batch) % max(1, n - tc.batch)
        batch = jnp.asarray(corpus[lo : lo + tc.batch])
        mu_t = teacher_means(batch)
        params, opt, loss = step_fn(params, opt, batch, mu_t, step)
        if step % 100 == 0 or step == tc.distill_steps - 1:
            log(f"[draft {draft_cfg.name}] step {step:4d} loss {float(loss):.4f} "
                f"({time.time() - t0:.0f}s)")
    return params


def mean_gap(target_params, draft_params, target_cfg, draft_cfg, corpus,
             n_batches: int = 8, batch: int = 32) -> float:
    """Mean L2 distance ||mu_p - mu_q|| at the last position — the Mahalanobis
    numerator that (with sigma) determines acceptance (Remark 5)."""
    gaps = []
    for i in range(n_batches):
        b = jnp.asarray(corpus[i * batch : (i + 1) * batch, :-1])
        mp = forward(target_params, b, target_cfg, use_pallas=False)[:, -1]
        mq = forward(draft_params, b, draft_cfg, use_pallas=False)[:, -1]
        gaps.append(jnp.sqrt(jnp.sum((mp - mq) ** 2, axis=-1)))
    return float(jnp.mean(jnp.concatenate(gaps)))
