"""L2: Timer-style patch-token decoder in JAX.

Decoder-only causal Transformer over time-series patches (Timer / Timer-XL
family, paper §2): patch embedding -> pre-RMSNorm blocks (causal MHA + SwiGLU
MLP) -> RMSNorm -> linear head emitting the *mean* of the isotropic Gaussian
next-patch distribution N(mu(H), sigma^2 I).  sigma is the paper's runtime
noise knob (swept in Tables 1/3/4), applied by the serving layer, so the
lowered graph outputs means only.

``forward(..., use_pallas=True)`` routes attention through the L1 Pallas
kernel so it lowers into the same HLO artifact; ``use_pallas=False`` uses the
pure-jnp reference (XLA-fused) — both variants are exported and the Rust
runtime can load either (config ``kernel = "pallas" | "fused"``).
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from .kernels import ref
from .kernels.attention import causal_attention

Params = dict[str, Any]


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Architecture hyperparameters for one model variant."""

    name: str
    patch: int = 24     # patch length P == Gaussian head dimension d
    n_ctx: int = 32     # Nmax patches (fixed AOT shape)
    d_model: int = 128
    n_layers: int = 4
    n_heads: int = 4
    d_ff: int = 256

    @property
    def d_head(self) -> int:
        assert self.d_model % self.n_heads == 0
        return self.d_model // self.n_heads

    def param_count(self) -> int:
        d, f, p = self.d_model, self.d_ff, self.patch
        per_layer = 4 * d * d + 3 * d * f + 2 * d  # qkv+out, swiglu, norms
        return p * d + d + self.n_ctx * d + self.n_layers * per_layer + d + d * p + p


# The paper's target/draft pair: draft is the 0.25x down-scaled variant
# (depth and width halved => ~1/8-1/4 of the parameters / FLOPs, matching the
# paper's 0.125x-0.5x exploration band).
TARGET = ModelConfig(name="timer-base", d_model=128, n_layers=4, n_heads=4, d_ff=256)
DRAFT = ModelConfig(name="timer-draft-0.25x", d_model=64, n_layers=2, n_heads=2, d_ff=128)
# Optional larger target for scale ablations ("timer-xl" stand-in).
TARGET_XL = ModelConfig(name="timer-xl", d_model=256, n_layers=6, n_heads=8, d_ff=512)

CONFIGS = {c.name: c for c in (TARGET, DRAFT, TARGET_XL)}


def init_params(cfg: ModelConfig, key: jax.Array) -> Params:
    """Scaled-normal initialization (0.02 / sqrt(2*layers) on residual outs)."""
    keys = iter(jax.random.split(key, 6 + 8 * cfg.n_layers))
    d, f, p = cfg.d_model, cfg.d_ff, cfg.patch
    std = 0.02
    resid_std = std / (2.0 * cfg.n_layers) ** 0.5

    def norm(k, shape, s):
        return (jax.random.normal(k, shape, jnp.float32) * s)

    params: Params = {
        "embed_w": norm(next(keys), (p, d), std),
        "embed_b": jnp.zeros((d,), jnp.float32),
        "pos": norm(next(keys), (cfg.n_ctx, d), std),
        "final_norm": jnp.ones((d,), jnp.float32),
        "head_w": norm(next(keys), (d, p), std),
        "head_b": jnp.zeros((p,), jnp.float32),
        "layers": [],
    }
    for _ in range(cfg.n_layers):
        params["layers"].append(
            {
                "ln1": jnp.ones((d,), jnp.float32),
                "wqkv": norm(next(keys), (d, 3 * d), std),
                "wo": norm(next(keys), (d, d), resid_std),
                "ln2": jnp.ones((d,), jnp.float32),
                "wg": norm(next(keys), (d, f), std),
                "wu": norm(next(keys), (d, f), std),
                "wd": norm(next(keys), (f, d), resid_std),
            }
        )
    return params


def _attention(x: jax.Array, layer: Params, cfg: ModelConfig, use_pallas: bool) -> jax.Array:
    b, n, d = x.shape
    qkv = x @ layer["wqkv"]  # [B, N, 3D]
    qkv = qkv.reshape(b, n, 3, cfg.n_heads, cfg.d_head)
    q, k, v = (qkv[:, :, i].transpose(0, 2, 1, 3) for i in range(3))  # [B,H,N,Dh]
    if use_pallas:
        o = causal_attention(q, k, v)
    else:
        o = ref.causal_attention_ref(q, k, v)
    o = o.transpose(0, 2, 1, 3).reshape(b, n, d)
    return o @ layer["wo"]


def _mlp(x: jax.Array, layer: Params) -> jax.Array:
    g = x @ layer["wg"]
    u = x @ layer["wu"]
    return (jax.nn.silu(g) * u) @ layer["wd"]


def forward(params: Params, tokens: jax.Array, cfg: ModelConfig,
            use_pallas: bool = False) -> jax.Array:
    """tokens [B, N, P] -> next-patch means [B, N, P].

    Output position i is mu(patch_{i+1} | patches_{<=i}); causality guarantees
    that one forward over history+gamma drafted patches yields every prefix
    conditional the batched validation pass needs (paper Alg. 1 line 4).
    """
    b, n, p = tokens.shape
    assert p == cfg.patch, (p, cfg.patch)
    assert n <= cfg.n_ctx, (n, cfg.n_ctx)
    x = tokens @ params["embed_w"] + params["embed_b"]
    x = x + params["pos"][:n]
    for layer in params["layers"]:
        x = x + _attention(ref.rmsnorm_ref(x, layer["ln1"]), layer, cfg, use_pallas)
        x = x + _mlp(ref.rmsnorm_ref(x, layer["ln2"]), layer)
    x = ref.rmsnorm_ref(x, params["final_norm"])
    return x @ params["head_w"] + params["head_b"]


def flops_per_forward(cfg: ModelConfig, batch: int, n: int) -> float:
    """Dense matmul FLOPs of one forward (the paper's \\hat{c} numerator)."""
    d, f, p = cfg.d_model, cfg.d_ff, cfg.patch
    per_tok = 2 * (p * d + 4 * d * d * cfg.n_layers + 3 * d * f * cfg.n_layers + d * p)
    attn = 4 * n * n * d * cfg.n_layers  # QK^T + PV per layer
    return batch * (n * per_tok + attn)


def flatten_params(params: Params) -> list[tuple[str, jax.Array]]:
    """Deterministic (name, tensor) list shared with the Rust loader."""
    out = [
        ("embed_w", params["embed_w"]),
        ("embed_b", params["embed_b"]),
        ("pos", params["pos"]),
    ]
    for i, layer in enumerate(params["layers"]):
        for k in ("ln1", "wqkv", "wo", "ln2", "wg", "wu", "wd"):
            out.append((f"layers.{i}.{k}", layer[k]))
    out += [
        ("final_norm", params["final_norm"]),
        ("head_w", params["head_w"]),
        ("head_b", params["head_b"]),
    ]
    return out
