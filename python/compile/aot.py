"""AOT entrypoint: train (cached) -> lower to HLO text -> export artifacts.

Python runs ONCE here (``make artifacts``); the Rust coordinator is fully
self-contained afterwards.  Interchange is HLO **text**, not serialized
HloModuleProto: jax >= 0.5 emits protos with 64-bit instruction ids that the
xla crate's xla_extension 0.5.1 rejects; the text parser reassigns ids and
round-trips cleanly (see /opt/xla-example/README.md).

Exports into --out-dir (default ../artifacts):
  {target,draft}_fwd_b{1,8,32}.hlo.txt     fused-attention forwards
  {target,draft}_fwd_pallas_b1.hlo.txt     Pallas-attention forwards (L1 path)
  accept_kernel.hlo.txt                    Pallas Gaussian-acceptance kernel
  weights_{target,draft}.bin               flat f32 LE dumps for the Rust
                                           native backend (parity tests)
  golden_*.bin                             pinned I/O vectors (Rust tests)
  manifest.json                            index of all of the above
"""

from __future__ import annotations

import argparse
import hashlib
import json
import pathlib
import sys

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import datagen, train
from .kernels import ref
from .kernels.gaussian_head import gaussian_accept
from .model import CONFIGS, DRAFT, TARGET, ModelConfig, flatten_params, forward

SCHEMA_VERSION = 4  # bump to invalidate caches on incompatible changes


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (the 0.5.1-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    # print_large_constants=True is load-bearing: the default elides weight
    # tensors as `constant({...})`, which parses back as zeros on the Rust
    # side and silently destroys numerics.
    return comp.as_hlo_text(print_large_constants=True)


def lower_forward(params, cfg: ModelConfig, batch: int, use_pallas: bool,
                  n_ctx: int | None = None) -> str:
    """Lower tokens[batch, n, patch] -> (means,) with weights baked in.

    ``n_ctx`` < cfg.n_ctx emits a *sequence-length-specialized* variant:
    XLA compiles a graph whose matmuls and attention are sized to the short
    context, so the runtime can route short prefixes (the common case during
    decoding: history 4 + gamma proposals) to a ~3-4x cheaper executable
    instead of always padding to the maximum context (see EXPERIMENTS.md
    §Perf).  Causality makes the shorter positional-embedding slice exact.
    """
    n = n_ctx or cfg.n_ctx

    def fn(tokens):
        return (forward(params, tokens, cfg, use_pallas=use_pallas),)

    spec = jax.ShapeDtypeStruct((batch, n, cfg.patch), jnp.float32)
    return to_hlo_text(jax.jit(fn).lower(spec))


def lower_accept_kernel(batch: int, dim: int) -> str:
    def fn(x, mu_p, mu_q, sigma, bias):
        return gaussian_accept(x, mu_p, mu_q, sigma, bias)

    v = jax.ShapeDtypeStruct((batch, dim), jnp.float32)
    s = jax.ShapeDtypeStruct((1,), jnp.float32)
    return to_hlo_text(jax.jit(fn).lower(v, v, v, s, s))


def dump_weights(params, path: pathlib.Path) -> list[dict]:
    """Flat f32 little-endian dump + tensor index for the Rust loader."""
    index, bufs, offset = [], [], 0
    for name, tensor in flatten_params(params):
        arr = np.asarray(tensor, dtype="<f4")
        index.append({"name": name, "shape": list(arr.shape), "offset": offset})
        bufs.append(arr.tobytes())
        offset += arr.size
    path.write_bytes(b"".join(bufs))
    return index


def config_hash(tc: train.TrainConfig) -> str:
    blob = json.dumps(
        {
            "schema": SCHEMA_VERSION,
            "target": TARGET.__dict__,
            "draft": DRAFT.__dict__,
            "train": tc.__dict__,
            "datasets": {k: v.__dict__ for k, v in datagen.SPECS.items()},
        },
        sort_keys=True, default=str,
    )
    return hashlib.sha256(blob.encode()).hexdigest()[:16]


def export_golden(out: pathlib.Path, params_t, params_d) -> dict:
    """Pinned vectors consumed by cargo tests (parity + datagen equivalence)."""
    golden: dict = {}
    # Model I/O parity: one real window from the etth1 test split.
    spec = datagen.SPECS["etth1"]
    win = datagen.sample_windows(spec, TARGET.patch, TARGET.n_ctx, 1, seed=999, split="test")
    tokens = jnp.asarray(win[:, :-1])  # [1, 32, 24]
    mu_t = forward(params_t, tokens, TARGET, use_pallas=False)
    mu_d = forward(params_d, tokens, DRAFT, use_pallas=False)
    np.asarray(tokens, "<f4").tofile(out / "golden_input.bin")
    np.asarray(mu_t, "<f4").tofile(out / "golden_target_means.bin")
    np.asarray(mu_d, "<f4").tofile(out / "golden_draft_means.bin")
    golden["model_io"] = {
        "input": "golden_input.bin",
        "target_means": "golden_target_means.bin",
        "draft_means": "golden_draft_means.bin",
        "shape": [1, TARGET.n_ctx, TARGET.patch],
    }
    # Acceptance kernel golden.
    key = jax.random.PRNGKey(42)
    kx, kp, kq = jax.random.split(key, 3)
    x = jax.random.normal(kx, (32, TARGET.patch), jnp.float32)
    mu_p = x + 0.3 * jax.random.normal(kp, x.shape, jnp.float32)
    mu_q = x + 0.3 * jax.random.normal(kq, x.shape, jnp.float32)
    lr, alpha = ref.gaussian_accept_ref(x, mu_p, mu_q, 0.5, bias=1.0)
    for name, arr in [("x", x), ("mu_p", mu_p), ("mu_q", mu_q),
                      ("log_ratio", lr), ("alpha", alpha)]:
        np.asarray(arr, "<f4").tofile(out / f"golden_accept_{name}.bin")
    golden["accept"] = {"batch": 32, "dim": TARGET.patch, "sigma": 0.5, "bias": 1.0}
    # Datagen equivalence: first 64 raw f64 samples of channel 0 per dataset,
    # plus normalization stats, so the Rust generator can prove it is the
    # same process.
    dg = {}
    for name, sp in datagen.SPECS.items():
        raw = datagen.generate(sp)
        train_end, _ = datagen.train_val_test_split(sp.length)
        mu = raw[:, :train_end].mean(axis=1)
        sd = raw[:, :train_end].std(axis=1)
        raw[0, :64].astype("<f8").tofile(out / f"golden_data_{name}.bin")
        dg[name] = {
            "file": f"golden_data_{name}.bin",
            "chan0_mean": float(mu[0]),
            "chan0_std": float(sd[0]),
        }
    golden["datagen"] = dg
    return golden


# (batch, n_ctx) shape grid: batch variants at full context for the
# dynamic batcher, plus short-sequence variants at b=1/b=8 for the decode
# hot path (shape specialization, §Perf).
SHAPE_GRID = ((1, 8), (1, 16), (1, 32), (8, 8), (8, 16), (8, 32), (32, 16), (32, 32))


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--quick", action="store_true",
                    help="tiny training run (CI); models underfit but all "
                         "artifact plumbing is exercised")
    ap.add_argument("--force", action="store_true", help="ignore caches")
    ap.add_argument("--skip-xl", action="store_true", default=True)
    args = ap.parse_args()

    out = pathlib.Path(args.out_dir).resolve()
    out.mkdir(parents=True, exist_ok=True)
    cache = out / "cache"
    cache.mkdir(exist_ok=True)

    tc = train.TrainConfig()
    if args.quick:
        tc = tc.scaled(0.05)
    chash = config_hash(tc) + ("-quick" if args.quick else "")

    manifest_path = out / "manifest.json"
    if manifest_path.exists() and not args.force:
        old = json.loads(manifest_path.read_text())
        if old.get("config_hash") == chash and all(
            (out / a["file"]).exists() for a in old.get("artifacts", [])
        ):
            print(f"artifacts up-to-date (hash {chash}); nothing to do")
            return

    # ---- train (cached by config hash) ----------------------------------
    wcache = cache / f"weights-{chash}.npz"
    if wcache.exists() and not args.force:
        print(f"loading cached weights {wcache.name}")
        blob = np.load(wcache)
        params_t = unflatten(TARGET, blob, "t.")
        params_d = unflatten(DRAFT, blob, "d.")
        corpus = train.build_corpus(tc, TARGET.n_ctx, TARGET.patch)
    else:
        print(f"building corpus ({tc.windows_per_dataset} windows x "
              f"{len(datagen.SPECS)} datasets)")
        corpus = train.build_corpus(tc, TARGET.n_ctx, TARGET.patch)
        params_t = train.pretrain_target(TARGET, tc, corpus)
        params_d = train.distill_draft(DRAFT, TARGET, params_t, tc, corpus)
        save = {}
        for pfx, p in (("t.", params_t), ("d.", params_d)):
            for name, tensor in flatten_params(p):
                save[pfx + name] = np.asarray(tensor)
        np.savez(wcache, **save)
    gap = train.mean_gap(params_t, params_d, TARGET, DRAFT, corpus)
    print(f"draft-target mean gap ||mu_p - mu_q|| = {gap:.4f} "
          f"(acceptance at sigma=0.5 ~ 2*Phi(-gap/(2*0.5)))")

    # ---- export ----------------------------------------------------------
    artifacts = []
    for mkey, cfg, params in (("target", TARGET, params_t), ("draft", DRAFT, params_d)):
        for b, n in SHAPE_GRID:
            f = f"{mkey}_fwd_b{b}_n{n}.hlo.txt" if n != cfg.n_ctx else f"{mkey}_fwd_b{b}.hlo.txt"
            print(f"lowering {f}")
            (out / f).write_text(lower_forward(params, cfg, b, use_pallas=False, n_ctx=n))
            artifacts.append({"file": f, "model": mkey, "batch": b, "n_ctx": n,
                              "kernel": "fused"})
        f = f"{mkey}_fwd_pallas_b1.hlo.txt"
        print(f"lowering {f} (Pallas interpret)")
        (out / f).write_text(lower_forward(params, cfg, 1, use_pallas=True))
        artifacts.append({"file": f, "model": mkey, "batch": 1, "n_ctx": cfg.n_ctx,
                          "kernel": "pallas"})

    print("lowering accept_kernel.hlo.txt")
    (out / "accept_kernel.hlo.txt").write_text(lower_accept_kernel(32, TARGET.patch))

    windex_t = dump_weights(params_t, out / "weights_target.bin")
    windex_d = dump_weights(params_d, out / "weights_draft.bin")
    golden = export_golden(out, params_t, params_d)

    manifest = {
        "schema": SCHEMA_VERSION,
        "config_hash": chash,
        "quick": args.quick,
        "patch": TARGET.patch,
        "n_ctx": TARGET.n_ctx,
        "batches": sorted({b for b, _ in SHAPE_GRID}),
        "shape_grid": [list(x) for x in SHAPE_GRID],
        "models": {
            "target": model_entry(TARGET, "weights_target.bin", windex_t),
            "draft": model_entry(DRAFT, "weights_draft.bin", windex_d),
        },
        "artifacts": artifacts,
        "accept_kernel": {"file": "accept_kernel.hlo.txt", "batch": 32,
                          "dim": TARGET.patch},
        "golden": golden,
        "distill": {"sigma": tc.distill_sigma, "tau": tc.distill_tau,
                    "w_kl": tc.distill_w_kl, "w_mse": tc.distill_w_mse,
                    "mean_gap": gap},
        "datasets": {k: v.__dict__ for k, v in datagen.SPECS.items()},
    }
    manifest_path.write_text(json.dumps(manifest, indent=2, default=str))
    print(f"wrote {manifest_path} ({len(artifacts)} HLO artifacts)")


def model_entry(cfg: ModelConfig, weights_file: str, index: list[dict]) -> dict:
    return {
        "name": cfg.name,
        "patch": cfg.patch, "n_ctx": cfg.n_ctx,
        "d_model": cfg.d_model, "n_layers": cfg.n_layers,
        "n_heads": cfg.n_heads, "d_ff": cfg.d_ff,
        "param_count": cfg.param_count(),
        "weights": weights_file,
        "tensors": index,
    }


def unflatten(cfg: ModelConfig, blob, prefix: str):
    """Rebuild the params pytree from an npz cache."""
    from .model import init_params  # structure template

    params = init_params(cfg, jax.random.PRNGKey(0))
    params["embed_w"] = jnp.asarray(blob[prefix + "embed_w"])
    params["embed_b"] = jnp.asarray(blob[prefix + "embed_b"])
    params["pos"] = jnp.asarray(blob[prefix + "pos"])
    params["final_norm"] = jnp.asarray(blob[prefix + "final_norm"])
    params["head_w"] = jnp.asarray(blob[prefix + "head_w"])
    params["head_b"] = jnp.asarray(blob[prefix + "head_b"])
    for i in range(cfg.n_layers):
        for k in ("ln1", "wqkv", "wo", "ln2", "wg", "wu", "wd"):
            params["layers"][i][k] = jnp.asarray(blob[f"{prefix}layers.{i}.{k}"])
    return params


if __name__ == "__main__":
    main()
