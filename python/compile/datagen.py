"""Synthetic ETT-like / Weather-like corpus generator.

The paper evaluates on ETTh1/ETTh2/ETTm2/Weather CSVs, which are not available
in this environment.  Per the substitution rule (DESIGN.md §3) we build
synthetic equivalents: multi-period sinusoids (diurnal + weekly for hourly
data, 15-min/10-min harmonics for the minute datasets) + AR(1) noise + slow
trend + rare level shifts.  Speculative-decoding acceptance depends on *local
regularity* and draft/target agreement, not on the exact ETT values, so this
preserves the behaviour the paper measures.

CRITICAL INVARIANT: this module is mirrored line-for-line by the Rust
generator in ``rust/src/data/synthetic.rs``.  Both use the same counter-based
SplitMix64 stream so that Python (training) and Rust (serving/eval) observe
the *same* datasets.  Golden vectors exported by aot.py pin the equivalence
(pytest ``test_datagen.py`` and cargo ``data::synthetic`` tests).
"""

from __future__ import annotations

import dataclasses

import numpy as np

# ---------------------------------------------------------------------------
# Counter-based SplitMix64 (vectorizable, identical in Rust).
# ---------------------------------------------------------------------------

_GOLDEN = np.uint64(0x9E3779B97F4A7C15)
_MIX1 = np.uint64(0xBF58476D1CE4E5B9)
_MIX2 = np.uint64(0x94D049BB133111EB)


def splitmix64(seed: int, idx: np.ndarray) -> np.ndarray:
    """Hash (seed, idx) -> uint64, vectorized over idx."""
    with np.errstate(over="ignore"):
        z = (np.uint64(seed) + (idx.astype(np.uint64) + np.uint64(1)) * _GOLDEN).astype(
            np.uint64
        )
        z = (z ^ (z >> np.uint64(30))) * _MIX1
        z = (z ^ (z >> np.uint64(27))) * _MIX2
        z = z ^ (z >> np.uint64(31))
    return z


def uniform01(seed: int, idx: np.ndarray) -> np.ndarray:
    """u in [0, 1) with 53-bit mantissa, same construction as Rust."""
    return (splitmix64(seed, idx) >> np.uint64(11)).astype(np.float64) * (2.0**-53)


def std_normal(seed: int, idx: np.ndarray) -> np.ndarray:
    """Box-Muller using the (2i, 2i+1) uniform pair; cos branch only.

    Discarding the sin branch wastes half the entropy but keeps the Python
    and Rust streams trivially identical (no carry-over state).
    """
    i = idx.astype(np.uint64)
    u1 = uniform01(seed, np.uint64(2) * i)
    u2 = uniform01(seed, np.uint64(2) * i + np.uint64(1))
    return np.sqrt(-2.0 * np.log1p(-u1)) * np.cos(2.0 * np.pi * u2)


# ---------------------------------------------------------------------------
# Dataset specs.
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class DatasetSpec:
    """Parameters of one synthetic dataset (mirrored in Rust)."""

    name: str
    seed: int
    channels: int
    length: int
    # Periods in samples and their amplitudes (shared across channels, with
    # per-channel phases drawn from the stream).
    periods: tuple[int, ...]
    amps: tuple[float, ...]
    # AR(1) noise.
    ar_phi: float
    noise_std: float
    # Linear trend per 1k samples (per channel, scaled by a stream draw).
    trend_per_k: float
    # Level shifts: expected count over the series and magnitude std.
    n_shifts: int
    shift_std: float


# Configs are tuned so the *ordering* of SD behaviour matches the paper:
# Weather is smoothest (highest acceptance, largest speedups), ETTh2 is
# noisiest of the hourly pair, ETTm2 sits between (fine-grained, regular).
SPECS: dict[str, DatasetSpec] = {
    "etth1": DatasetSpec(
        name="etth1", seed=101, channels=7, length=14400,
        periods=(24, 168), amps=(1.0, 0.45), ar_phi=0.72, noise_std=0.32,
        trend_per_k=0.04, n_shifts=6, shift_std=0.5,
    ),
    "etth2": DatasetSpec(
        name="etth2", seed=202, channels=7, length=14400,
        periods=(24, 168), amps=(0.9, 0.35), ar_phi=0.65, noise_std=0.52,
        trend_per_k=0.06, n_shifts=10, shift_std=0.8,
    ),
    "ettm2": DatasetSpec(
        name="ettm2", seed=303, channels=7, length=28800,
        periods=(96, 672), amps=(1.0, 0.40), ar_phi=0.80, noise_std=0.28,
        trend_per_k=0.02, n_shifts=6, shift_std=0.4,
    ),
    "weather": DatasetSpec(
        name="weather", seed=404, channels=21, length=14400,
        periods=(144, 1008), amps=(1.1, 0.50), ar_phi=0.85, noise_std=0.14,
        trend_per_k=0.03, n_shifts=3, shift_std=0.3,
    ),
}

# Sub-stream tags (keep in sync with Rust).
_TAG_PHASE = 1
_TAG_AMP = 2
_TAG_NOISE = 3
_TAG_TREND = 4
_TAG_SHIFT_POS = 5
_TAG_SHIFT_MAG = 6


def _chan_seed(spec: DatasetSpec, tag: int, channel: int) -> int:
    return (spec.seed * 1_000_003 + tag * 10_007 + channel) & 0xFFFFFFFFFFFFFFFF


def generate(spec: DatasetSpec) -> np.ndarray:
    """Return raw series, shape [channels, length], float64."""
    t = np.arange(spec.length, dtype=np.float64)
    out = np.empty((spec.channels, spec.length), dtype=np.float64)
    for c in range(spec.channels):
        phases = uniform01(_chan_seed(spec, _TAG_PHASE, c), np.arange(len(spec.periods)))
        ampj = uniform01(_chan_seed(spec, _TAG_AMP, c), np.arange(len(spec.periods)))
        y = np.zeros(spec.length, dtype=np.float64)
        for k, (period, amp) in enumerate(zip(spec.periods, spec.amps)):
            a = amp * (0.75 + 0.5 * ampj[k])
            y += a * np.sin(2.0 * np.pi * (t / period + phases[k]))
        # AR(1) noise, sequential recursion (identical loop in Rust).
        eta = std_normal(_chan_seed(spec, _TAG_NOISE, c), np.arange(spec.length))
        e = np.empty(spec.length, dtype=np.float64)
        prev = 0.0
        for i in range(spec.length):
            prev = spec.ar_phi * prev + spec.noise_std * eta[i]
            e[i] = prev
        y += e
        # Slow linear trend.
        tr = uniform01(_chan_seed(spec, _TAG_TREND, c), np.arange(1))[0] - 0.5
        y += (2.0 * tr * spec.trend_per_k / 1000.0) * t
        # Rare level shifts.
        pos = uniform01(_chan_seed(spec, _TAG_SHIFT_POS, c), np.arange(spec.n_shifts))
        mag = std_normal(_chan_seed(spec, _TAG_SHIFT_MAG, c), np.arange(spec.n_shifts))
        for s in range(spec.n_shifts):
            start = int(pos[s] * spec.length)
            y[start:] += spec.shift_std * mag[s]
        out[c] = y
    return out


def train_val_test_split(length: int) -> tuple[int, int]:
    """Return (train_end, val_end); test is the remainder. 70/10/20."""
    train_end = int(length * 0.7)
    val_end = int(length * 0.8)
    return train_end, val_end


def normalized(spec: DatasetSpec) -> np.ndarray:
    """Z-score by per-channel train-split statistics (standard protocol)."""
    raw = generate(spec)
    train_end, _ = train_val_test_split(spec.length)
    mu = raw[:, :train_end].mean(axis=1, keepdims=True)
    sd = raw[:, :train_end].std(axis=1, keepdims=True)
    sd = np.maximum(sd, 1e-8)
    return (raw - mu) / sd


def patchify(series_1d: np.ndarray, patch: int) -> np.ndarray:
    """[L] -> [L // patch, patch], truncating the tail."""
    n = len(series_1d) // patch
    return series_1d[: n * patch].reshape(n, patch)


def sample_windows(
    spec: DatasetSpec,
    patch: int,
    n_ctx: int,
    n_windows: int,
    seed: int,
    split: str = "train",
) -> np.ndarray:
    """Random training windows of n_ctx+1 consecutive patches.

    Returns float32 [n_windows, n_ctx + 1, patch].  Model input is patches
    [0 .. n_ctx-1], teacher-forced targets are patches [1 .. n_ctx].
    """
    data = normalized(spec)
    train_end, val_end = train_val_test_split(spec.length)
    if split == "train":
        lo, hi = 0, train_end
    elif split == "val":
        lo, hi = train_end, val_end
    else:
        lo, hi = val_end, spec.length
    span = (n_ctx + 1) * patch
    u_ch = uniform01(seed * 7 + 1, np.arange(n_windows))
    u_of = uniform01(seed * 7 + 2, np.arange(n_windows))
    out = np.empty((n_windows, n_ctx + 1, patch), dtype=np.float32)
    for i in range(n_windows):
        c = int(u_ch[i] * spec.channels)
        start = lo + int(u_of[i] * (hi - lo - span))
        w = data[c, start : start + span]
        out[i] = w.reshape(n_ctx + 1, patch).astype(np.float32)
    return out
