"""Pure-jnp oracles for the Pallas kernels (L1 correctness ground truth).

Every Pallas kernel in this package has an exact reference here; pytest
asserts allclose between kernel and oracle across shape/dtype sweeps
(``python/tests/test_kernels.py``).  These references are also what the
training loop uses (interpret-mode Pallas is too slow to train through).
"""

from __future__ import annotations

import jax.numpy as jnp


def causal_attention_ref(q, k, v, scale: float | None = None):
    """Causal scaled-dot-product attention.

    q, k, v: [B, H, N, Dh].  Returns [B, H, N, Dh].
    """
    if scale is None:
        scale = 1.0 / (q.shape[-1] ** 0.5)
    logits = jnp.einsum("bhqd,bhkd->bhqk", q, k) * scale
    n = q.shape[2]
    mask = jnp.tril(jnp.ones((n, n), dtype=bool))
    logits = jnp.where(mask[None, None], logits, -jnp.inf)
    probs = jnp.exp(logits - logits.max(axis=-1, keepdims=True))
    probs = probs / probs.sum(axis=-1, keepdims=True)
    return jnp.einsum("bhqk,bhkd->bhqd", probs, v)


def gaussian_accept_ref(x, mu_p, mu_q, sigma, bias: float = 1.0):
    """Log-space acceptance for isotropic Gaussian heads (paper Eq. 7/8).

    x, mu_p, mu_q: [B, d]; sigma: scalar or [B].
    Returns (log_ratio [B], alpha [B]) with
      log_ratio = -(||x-mu_p||^2 - ||x-mu_q||^2) / (2 sigma^2) + log(bias)
      alpha     = min(1, exp(log_ratio)).
    ``bias`` is the paper's tolerance lambda (Table 1/5 "bias" rows).
    """
    sigma = jnp.asarray(sigma)
    dp = jnp.sum((x - mu_p) ** 2, axis=-1)
    dq = jnp.sum((x - mu_q) ** 2, axis=-1)
    log_ratio = -(dp - dq) / (2.0 * sigma**2) + jnp.log(bias)
    # exp(min(lr,0)) == min(1, exp(lr)), and it cannot overflow for lr >> 0.
    alpha = jnp.exp(jnp.minimum(log_ratio, 0.0))
    return log_ratio, alpha


def rmsnorm_ref(x, w, eps: float = 1e-6):
    """RMSNorm over the last axis: x * w / rms(x)."""
    ms = jnp.mean(x.astype(jnp.float32) ** 2, axis=-1, keepdims=True)
    return (x * (1.0 / jnp.sqrt(ms + eps)) * w).astype(x.dtype)
