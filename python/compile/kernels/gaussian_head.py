"""Pallas fused Gaussian acceptance kernel (paper Eq. 7/8).

Computes, for a batch of proposed patches x and the two model means, the
log-likelihood ratio and the log-space acceptance probability of speculative
decoding in a single fused pass:

    log_ratio = -(||x - mu_p||^2 - ||x - mu_q||^2) / (2 sigma^2) + log(bias)
    alpha     = exp(min(log_ratio, 0))        # == min(1, p/q * bias)

The subtraction of squared norms is numerically the dangerous spot (two
large nearby numbers); the kernel follows the paper's log-domain rule (§3.6)
and fuses the difference-of-squares as sum((mu_q - mu_p) * (2x - mu_p -
mu_q)), which is exact algebraically and avoids forming the two large norms.

This kernel is exported as its own HLO artifact (``accept_kernel.hlo.txt``)
and exercised from Rust as a cross-language validation path; the serving hot
loop uses the native Rust implementation of the same formula (bit-compared
in ``rust/tests/xla_integration.rs``).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _accept_kernel(x_ref, mup_ref, muq_ref, sig_ref, bias_ref, lr_ref, a_ref):
    x = x_ref[...].astype(jnp.float32)
    mup = mup_ref[...].astype(jnp.float32)
    muq = muq_ref[...].astype(jnp.float32)
    sigma = sig_ref[0]
    log_bias = jnp.log(bias_ref[0])
    # ||x-mu_p||^2 - ||x-mu_q||^2 == sum((mu_q - mu_p) * (2x - mu_p - mu_q))
    diff = jnp.sum((muq - mup) * (2.0 * x - mup - muq), axis=-1)
    log_ratio = -diff / (2.0 * sigma * sigma) + log_bias
    lr_ref[...] = log_ratio
    a_ref[...] = jnp.exp(jnp.minimum(log_ratio, 0.0))


@functools.partial(jax.jit, static_argnames=("block_b",))
def gaussian_accept(x, mu_p, mu_q, sigma, bias, block_b: int = 32):
    """Fused acceptance.  x, mu_p, mu_q: [B, d]; sigma, bias: [1] scalars.

    Returns (log_ratio [B], alpha [B]), both float32.
    """
    b, d = x.shape
    block_b = min(block_b, b)
    if b % block_b:
        raise ValueError(f"B={b} not divisible by block_b={block_b}")
    grid = (b // block_b,)
    return pl.pallas_call(
        _accept_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_b, d), lambda i: (i, 0)),
            pl.BlockSpec((block_b, d), lambda i: (i, 0)),
            pl.BlockSpec((block_b, d), lambda i: (i, 0)),
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((block_b,), lambda i: (i,)),
            pl.BlockSpec((block_b,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b,), jnp.float32),
            jax.ShapeDtypeStruct((b,), jnp.float32),
        ],
        interpret=True,  # CPU PJRT path; see attention.py module doc
    )(x, mu_p, mu_q, sigma, bias)
