"""Pallas fused causal attention — the L1 compute hot-spot.

The paper's targets (Timer / Timer-XL) run flash-/memory-efficient attention
on CUDA (paper §4.1.6).  On this stack the same IO-minimizing schedule is
expressed as a Pallas HBM<->VMEM block schedule (DESIGN.md §Hardware-
Adaptation):

* grid = (batch, heads, q-blocks): each program owns one ``block_q x d_head``
  query tile resident in VMEM (the SRAM tile of the CUDA version);
* K/V are streamed tile-by-tile with ``pl.load`` (the HBM->VMEM pipeline a
  threadblock would issue), with an **online-softmax** accumulator so no
  [N, N] score matrix ever materializes;
* the causal frontier prunes the K-block loop, exactly like flash-attention's
  block skipping — a query tile only visits ``ceil((q_end)/block_k)`` tiles;
* the two matmuls (QK^T, PV) are MXU-shaped ([block_q, d_head] x
  [d_head, block_k]); with bf16 inputs on real TPU these hit the systolic
  array.  ``interpret=True`` is mandatory here: CPU PJRT cannot execute the
  Mosaic custom-call a real TPU lowering would emit, so the kernel lowers to
  plain HLO (correctness path); TPU performance is *estimated* in
  EXPERIMENTS.md §Perf from the BlockSpec footprint.

Correctness oracle: ``ref.causal_attention_ref`` (pytest sweeps shapes).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = float("-inf")


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, *, block_q: int, block_k: int, scale: float):
    """One (batch, head, q-block) program: online-softmax over K tiles."""
    qi = pl.program_id(2)
    q = q_ref[0, 0].astype(jnp.float32) * scale  # [block_q, dh] VMEM tile
    dh = q.shape[-1]
    q_start = qi * block_q

    m0 = jnp.full((block_q,), NEG_INF, jnp.float32)
    l0 = jnp.zeros((block_q,), jnp.float32)
    acc0 = jnp.zeros((block_q, dh), jnp.float32)

    # Causal frontier: K tiles strictly past the last query row are skipped.
    n_kb = (q_start + block_q + block_k - 1) // block_k

    def body(kb, carry):
        m, l, acc = carry
        k = pl.load(k_ref, (0, 0, pl.dslice(kb * block_k, block_k), slice(None)))
        v = pl.load(v_ref, (0, 0, pl.dslice(kb * block_k, block_k), slice(None)))
        s = q @ k.astype(jnp.float32).T  # [block_q, block_k] (MXU matmul)
        qpos = q_start + jax.lax.iota(jnp.int32, block_q)
        kpos = kb * block_k + jax.lax.iota(jnp.int32, block_k)
        s = jnp.where(qpos[:, None] >= kpos[None, :], s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m - m_new)
        l_new = corr * l + p.sum(axis=-1)
        acc_new = acc * corr[:, None] + p @ v.astype(jnp.float32)
        return m_new, l_new, acc_new

    m, l, acc = jax.lax.fori_loop(0, n_kb, body, (m0, l0, acc0))
    o_ref[0, 0] = (acc / l[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_q", "block_k"))
def causal_attention(q, k, v, block_q: int = 16, block_k: int = 16):
    """Fused causal attention.  q, k, v: [B, H, N, Dh] -> [B, H, N, Dh].

    N must be divisible by block_q and block_k (the model pads its context
    to Nmax, so this holds by construction on the AOT path).
    """
    b, h, n, dh = q.shape
    block_q = min(block_q, n)
    block_k = min(block_k, n)
    if n % block_q or n % block_k:
        raise ValueError(f"N={n} not divisible by blocks ({block_q},{block_k})")
    scale = 1.0 / (dh**0.5)
    grid = (b, h, n // block_q)
    return pl.pallas_call(
        functools.partial(_flash_kernel, block_q=block_q, block_k=block_k, scale=scale),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, dh), lambda bi, hi, qi: (bi, hi, qi, 0)),
            pl.BlockSpec((1, 1, n, dh), lambda bi, hi, qi: (bi, hi, 0, 0)),
            pl.BlockSpec((1, 1, n, dh), lambda bi, hi, qi: (bi, hi, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, dh), lambda bi, hi, qi: (bi, hi, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, n, dh), q.dtype),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls (see module doc)
    )(q, k, v)


def vmem_footprint_bytes(n: int, dh: int, block_q: int = 16, block_k: int = 16,
                         dtype_bytes: int = 4) -> dict:
    """Analytic VMEM/MXU model used by the §Perf TPU estimate (no execution).

    Returns per-program VMEM bytes and the arithmetic intensity of the two
    matmuls; EXPERIMENTS.md §Perf combines this with MXU peak to estimate
    real-TPU efficiency (interpret-mode wallclock is *not* a TPU proxy).
    """
    q_tile = block_q * dh * dtype_bytes
    kv_tile = 2 * block_k * dh * dtype_bytes
    acc = block_q * dh * 4 + 2 * block_q * 4  # fp32 accumulator + m/l rows
    flops = 2 * 2 * block_q * block_k * dh  # QK^T and PV per tile pair
    bytes_moved = kv_tile  # K/V streamed per tile; Q/acc resident
    return {
        "vmem_bytes": q_tile + kv_tile + acc,
        "flops_per_tile": flops,
        "bytes_per_tile": bytes_moved,
        "arith_intensity": flops / bytes_moved,
        "n_tiles": (n // block_q) * (n // block_k) / 2,  # causal halves the work
    }
