"""L2 model tests: shapes, causality, pallas/fused parity, FLOPs model."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.model import (
    CONFIGS, DRAFT, TARGET, ModelConfig, flatten_params, flops_per_forward,
    forward, init_params,
)


@pytest.fixture(scope="module")
def tiny():
    cfg = ModelConfig(name="tiny", patch=8, n_ctx=16, d_model=32, n_layers=2,
                      n_heads=2, d_ff=64)
    params = init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def test_forward_shapes(tiny):
    cfg, params = tiny
    x = jnp.zeros((3, 16, 8), jnp.float32)
    y = forward(params, x, cfg)
    assert y.shape == (3, 16, 8)
    assert bool(jnp.isfinite(y).all())


def test_forward_shorter_context(tiny):
    cfg, params = tiny
    x = jnp.zeros((1, 5, 8), jnp.float32)
    assert forward(params, x, cfg).shape == (1, 5, 8)


def test_causality(tiny):
    cfg, params = tiny
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((1, 16, 8)), jnp.float32)
    y0 = forward(params, x, cfg)
    x2 = x.at[:, 10:].add(1.0)
    y1 = forward(params, x2, cfg)
    np.testing.assert_allclose(np.asarray(y0[:, :10]), np.asarray(y1[:, :10]), atol=1e-5)
    assert np.abs(np.asarray(y0[:, 10:]) - np.asarray(y1[:, 10:])).max() > 1e-4


def test_pallas_and_fused_agree(tiny):
    cfg, params = tiny
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.standard_normal((1, 16, 8)), jnp.float32)
    y_fused = forward(params, x, cfg, use_pallas=False)
    y_pallas = forward(params, x, cfg, use_pallas=True)
    np.testing.assert_allclose(np.asarray(y_fused), np.asarray(y_pallas),
                               atol=5e-5, rtol=5e-5)


def test_param_count_matches_flatten(tiny):
    cfg, params = tiny
    total = sum(int(np.prod(t.shape)) for _, t in flatten_params(params))
    assert total == cfg.param_count()


def test_draft_is_quarter_scale():
    # The paper's 0.25x draft band: parameter ratio in [0.1, 0.35].
    ratio = DRAFT.param_count() / TARGET.param_count()
    assert 0.05 < ratio < 0.35, ratio


def test_flops_model_monotone():
    assert flops_per_forward(TARGET, 1, 32) > flops_per_forward(DRAFT, 1, 32)
    assert flops_per_forward(TARGET, 2, 32) == 2 * flops_per_forward(TARGET, 1, 32)
    assert flops_per_forward(TARGET, 1, 32) > flops_per_forward(TARGET, 1, 16)


def test_configs_registry():
    assert set(CONFIGS) >= {"timer-base", "timer-draft-0.25x", "timer-xl"}
    for cfg in CONFIGS.values():
        assert cfg.d_model % cfg.n_heads == 0


def test_deterministic_init():
    cfg = ModelConfig(name="t", patch=4, n_ctx=8, d_model=16, n_layers=1,
                      n_heads=2, d_ff=32)
    a = init_params(cfg, jax.random.PRNGKey(7))
    b = init_params(cfg, jax.random.PRNGKey(7))
    for (na, ta), (nb, tb) in zip(flatten_params(a), flatten_params(b)):
        assert na == nb
        np.testing.assert_array_equal(np.asarray(ta), np.asarray(tb))
