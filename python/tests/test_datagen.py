"""Datagen tests: RNG golden values (the Rust-equivalence contract),
generator determinism, split/normalization, window sampling."""

import numpy as np
import pytest

from compile import datagen


def test_splitmix_golden_values():
    # Pinned values asserted identically in rust/src/util/rng.rs.
    assert int(datagen.splitmix64(42, np.arange(1))[0]) == 0xBDD7_3226_2FEB_6E95
    assert int(datagen.splitmix64(0, np.arange(1))[0]) == 0xE220_A839_7B1D_CDAF
    assert abs(float(datagen.uniform01(42, np.arange(1))[0]) - 0.7415648787718233) < 1e-15
    assert abs(float(datagen.std_normal(3, np.arange(5))[3]) - 0.4124328000730101) < 1e-12


def test_uniform_range_and_normal_moments():
    u = datagen.uniform01(9, np.arange(20000))
    assert u.min() >= 0.0 and u.max() < 1.0
    assert abs(u.mean() - 0.5) < 0.01
    z = datagen.std_normal(9, np.arange(50000))
    assert abs(z.mean()) < 0.02
    assert abs(z.var() - 1.0) < 0.03


@pytest.mark.parametrize("name", list(datagen.SPECS))
def test_generate_deterministic_and_finite(name):
    spec = datagen.SPECS[name]
    a = datagen.generate(spec)
    assert a.shape == (spec.channels, spec.length)
    assert np.isfinite(a).all()
    b = datagen.generate(spec)
    np.testing.assert_array_equal(a[:, :256], b[:, :256])


def test_channels_differ():
    a = datagen.generate(datagen.SPECS["etth1"])
    assert np.abs(a[0, :100] - a[1, :100]).max() > 0.1


def test_normalized_train_stats():
    data = datagen.normalized(datagen.SPECS["etth2"])
    train_end, _ = datagen.train_val_test_split(data.shape[1])
    tr = data[:, :train_end]
    np.testing.assert_allclose(tr.mean(axis=1), 0.0, atol=1e-10)
    np.testing.assert_allclose(tr.std(axis=1), 1.0, atol=1e-10)


def test_roughness_ordering():
    # Mirrors rust data::synthetic::datasets_have_expected_roughness_ordering.
    def rough(name):
        d = datagen.normalized(datagen.SPECS[name])
        return np.abs(np.diff(d[:, :2000], axis=1)).mean()

    assert rough("weather") < rough("etth1") < rough("etth2")


def test_patchify():
    x = np.arange(50, dtype=np.float64)
    p = datagen.patchify(x, 24)
    assert p.shape == (2, 24)
    assert p[1, 0] == 24


def test_sample_windows_shapes_and_split():
    spec = datagen.SPECS["etth1"]
    w = datagen.sample_windows(spec, 24, 8, 16, seed=3, split="train")
    assert w.shape == (16, 9, 24)
    assert w.dtype == np.float32
    assert np.isfinite(w).all()
    # Windows are contiguous: consecutive patches continue the series.
    flat = w[0].reshape(-1)
    assert np.abs(np.diff(flat)).max() < 5.0  # no discontinuity artifacts


def test_sample_windows_deterministic():
    spec = datagen.SPECS["weather"]
    a = datagen.sample_windows(spec, 24, 4, 8, seed=5)
    b = datagen.sample_windows(spec, 24, 4, 8, seed=5)
    np.testing.assert_array_equal(a, b)
    c = datagen.sample_windows(spec, 24, 4, 8, seed=6)
    assert np.abs(a - c).max() > 1e-6
