"""L1 correctness: Pallas kernels vs pure-jnp oracles across shape/dtype
sweeps (the hypothesis-style grid is explicit so failures are reproducible).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.kernels import ref
from compile.kernels.attention import causal_attention, vmem_footprint_bytes
from compile.kernels.gaussian_head import gaussian_accept

ATTN_SHAPES = [
    # (batch, heads, seq, d_head, block_q, block_k)
    (1, 1, 16, 8, 16, 16),
    (1, 2, 32, 16, 16, 16),
    (2, 4, 32, 32, 16, 16),
    (1, 1, 32, 32, 8, 8),
    (3, 2, 64, 16, 16, 32),
    (1, 4, 32, 32, 32, 32),  # single q block
    (2, 2, 48, 8, 16, 8),    # uneven block mix
]


@pytest.mark.parametrize("b,h,n,dh,bq,bk", ATTN_SHAPES)
def test_attention_matches_ref(b, h, n, dh, bq, bk):
    rng = np.random.default_rng(hash((b, h, n, dh)) % 2**32)
    q, k, v = (
        jnp.asarray(rng.standard_normal((b, h, n, dh)), jnp.float32) for _ in range(3)
    )
    out = causal_attention(q, k, v, block_q=bq, block_k=bk)
    want = ref.causal_attention_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=2e-5, rtol=2e-5)


def test_attention_is_causal():
    # Perturbing position t must not change outputs before t.
    rng = np.random.default_rng(0)
    q, k, v = (
        jnp.asarray(rng.standard_normal((1, 2, 32, 16)), jnp.float32) for _ in range(3)
    )
    base = np.asarray(causal_attention(q, k, v))
    k2 = k.at[:, :, 20:].add(5.0)
    v2 = v.at[:, :, 20:].add(5.0)
    pert = np.asarray(causal_attention(q, k2, v2))
    np.testing.assert_allclose(base[:, :, :20], pert[:, :, :20], atol=1e-6)
    assert np.abs(base[:, :, 20:] - pert[:, :, 20:]).max() > 1e-3


def test_attention_scale_invariance_of_softmax():
    # Adding a constant to all logits (via uniform k shift along d) leaves
    # attention unchanged only in degenerate cases; instead verify the
    # softmax normalization: outputs are convex combinations of v rows.
    rng = np.random.default_rng(1)
    q, k = (jnp.asarray(rng.standard_normal((1, 1, 16, 8)), jnp.float32) for _ in range(2))
    v = jnp.ones((1, 1, 16, 8), jnp.float32)
    out = np.asarray(causal_attention(q, k, v))
    np.testing.assert_allclose(out, 1.0, atol=1e-5)


def test_attention_rejects_indivisible():
    q = jnp.zeros((1, 1, 30, 8), jnp.float32)
    with pytest.raises(ValueError):
        causal_attention(q, q, q, block_q=16, block_k=16)


def test_vmem_model_sane():
    m = vmem_footprint_bytes(32, 32, 16, 16)
    assert m["vmem_bytes"] < 16 * 1024 * 1024, "fits VMEM"
    assert m["arith_intensity"] > 1.0


ACCEPT_SHAPES = [(32, 24), (32, 8), (64, 24), (96, 4), (32, 1)]


@pytest.mark.parametrize("b,d", ACCEPT_SHAPES)
@pytest.mark.parametrize("sigma,bias", [(0.5, 1.0), (0.3, 1.0), (0.8, 1.5), (1.2, 3.0)])
def test_gaussian_accept_matches_ref(b, d, sigma, bias):
    rng = np.random.default_rng(hash((b, d, sigma)) % 2**32)
    x = jnp.asarray(rng.standard_normal((b, d)), jnp.float32)
    mu_p = x + jnp.asarray(0.3 * rng.standard_normal((b, d)), jnp.float32)
    mu_q = x + jnp.asarray(0.3 * rng.standard_normal((b, d)), jnp.float32)
    lr, alpha = gaussian_accept(
        x, mu_p, mu_q,
        jnp.array([sigma], jnp.float32), jnp.array([bias], jnp.float32),
        block_b=32,
    )
    lr_ref, a_ref = ref.gaussian_accept_ref(x, mu_p, mu_q, sigma, bias=bias)
    np.testing.assert_allclose(np.asarray(lr), np.asarray(lr_ref), atol=2e-4, rtol=2e-4)
    np.testing.assert_allclose(np.asarray(alpha), np.asarray(a_ref), atol=2e-5)


def test_accept_alpha_bounds_and_direction():
    # If x == mu_p, target likes x at least as much: alpha == 1.
    x = jnp.zeros((32, 24), jnp.float32)
    far = jnp.full((32, 24), 3.0, jnp.float32)
    one = jnp.array([1.0], jnp.float32)
    half = jnp.array([0.5], jnp.float32)
    _, a = gaussian_accept(x, x, far, half, one)
    np.testing.assert_allclose(np.asarray(a), 1.0)
    _, a = gaussian_accept(x, far, x, half, one)
    assert np.asarray(a).max() < 1e-6


def test_accept_no_overflow_extreme_ratio():
    x = jnp.full((32, 24), 50.0, jnp.float32)
    mu_q = jnp.full((32, 24), -50.0, jnp.float32)
    sig = jnp.array([0.05], jnp.float32)
    one = jnp.array([1.0], jnp.float32)
    lr, a = gaussian_accept(x, x, mu_q, sig, one)
    assert np.isfinite(np.asarray(a)).all()
    np.testing.assert_allclose(np.asarray(a), 1.0)


def test_rmsnorm_ref_unit_scale():
    x = jnp.asarray(np.random.default_rng(2).standard_normal((4, 16)), jnp.float32)
    y = ref.rmsnorm_ref(x, jnp.ones((16,), jnp.float32))
    rms = np.sqrt(np.mean(np.asarray(y) ** 2, axis=-1))
    np.testing.assert_allclose(rms, 1.0, atol=1e-3)
