"""AOT pipeline tests: HLO text export invariants, weight dumps, manifest
schema, and (when artifacts exist) consistency of the exported goldens."""

import json
import pathlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, train
from compile.model import DRAFT, TARGET, ModelConfig, forward, init_params

ARTIFACTS = pathlib.Path(__file__).resolve().parents[2] / "artifacts"


@pytest.fixture(scope="module")
def tiny_params():
    cfg = ModelConfig(name="tiny", patch=4, n_ctx=8, d_model=16, n_layers=1,
                      n_heads=2, d_ff=32)
    return cfg, init_params(cfg, jax.random.PRNGKey(0))


def test_hlo_text_has_full_constants(tiny_params):
    cfg, params = tiny_params
    text = aot.lower_forward(params, cfg, batch=1, use_pallas=False)
    assert text.startswith("HloModule")
    # The elided form `constant({...})` must never appear: it parses as
    # zeros on the Rust side (the bug print_large_constants=True fixes).
    assert "constant({...})" not in text
    # Entry layout matches [1, n_ctx, patch] -> tuple.
    assert "f32[1,8,4]" in text


def test_hlo_pallas_variant_lowers(tiny_params):
    cfg, params = tiny_params
    text = aot.lower_forward(params, cfg, batch=1, use_pallas=True)
    assert text.startswith("HloModule")
    assert "constant({...})" not in text


def test_accept_kernel_lowers():
    text = aot.lower_accept_kernel(batch=32, dim=24)
    assert text.startswith("HloModule")
    assert "f32[32,24]" in text


def test_dump_weights_roundtrip(tiny_params, tmp_path):
    cfg, params = tiny_params
    blob = tmp_path / "w.bin"
    index = aot.dump_weights(params, blob)
    raw = np.fromfile(blob, dtype="<f4")
    total = sum(int(np.prod(e["shape"])) for e in index)
    assert len(raw) == total == cfg.param_count()
    # Spot-check one tensor: offsets slice out exactly the right values.
    e = next(i for i in index if i["name"] == "embed_w")
    got = raw[e["offset"]: e["offset"] + int(np.prod(e["shape"]))]
    np.testing.assert_array_equal(got, np.asarray(params["embed_w"]).ravel())


def test_config_hash_stable_and_sensitive():
    tc = train.TrainConfig()
    assert aot.config_hash(tc) == aot.config_hash(tc)
    tc2 = train.TrainConfig(steps=tc.steps + 1)
    assert aot.config_hash(tc) != aot.config_hash(tc2)


def test_unflatten_roundtrip(tiny_params, tmp_path):
    _, params = tiny_params
    # Save/load via the cache format used by aot.main.
    save = {"t." + name: np.asarray(t) for name, t in
            __import__("compile.model", fromlist=["flatten_params"]).flatten_params(params)}
    np.savez(tmp_path / "w.npz", **save)
    blob = np.load(tmp_path / "w.npz")
    cfg = ModelConfig(name="tiny", patch=4, n_ctx=8, d_model=16, n_layers=1,
                      n_heads=2, d_ff=32)
    restored = aot.unflatten(cfg, blob, "t.")
    x = jnp.ones((1, 8, 4), jnp.float32)
    np.testing.assert_allclose(
        np.asarray(forward(params, x, cfg)),
        np.asarray(forward(restored, x, cfg)),
        atol=1e-6,
    )


# ---------------------------------------------------------------------------
# Artifact-dependent checks (skipped until `make artifacts`).
# ---------------------------------------------------------------------------

needs_artifacts = pytest.mark.skipif(
    not (ARTIFACTS / "manifest.json").exists(), reason="run `make artifacts`"
)


@needs_artifacts
def test_manifest_schema():
    m = json.loads((ARTIFACTS / "manifest.json").read_text())
    assert m["patch"] == TARGET.patch
    assert m["n_ctx"] == TARGET.n_ctx
    for key in ("target", "draft"):
        entry = m["models"][key]
        assert (ARTIFACTS / entry["weights"]).exists()
        assert entry["param_count"] > 0
    for a in m["artifacts"]:
        assert (ARTIFACTS / a["file"]).exists(), a["file"]
        assert a["kernel"] in ("fused", "pallas")
    assert m["models"]["draft"]["param_count"] * 3 < m["models"]["target"]["param_count"]


@needs_artifacts
def test_golden_target_means_match_recomputation():
    """The exported golden output must equal a fresh forward through the
    cached weights — guards against manifest/weights/golden skew."""
    m = json.loads((ARTIFACTS / "manifest.json").read_text())
    cache = ARTIFACTS / "cache" / f"weights-{m['config_hash']}.npz"
    if not cache.exists():
        pytest.skip("weights cache cleared")
    blob = np.load(cache)
    params = aot.unflatten(TARGET, blob, "t.")
    tokens = np.fromfile(ARTIFACTS / "golden_input.bin", dtype="<f4").reshape(1, 32, 24)
    want = np.fromfile(ARTIFACTS / "golden_target_means.bin", dtype="<f4").reshape(1, 32, 24)
    got = np.asarray(forward(params, jnp.asarray(tokens), TARGET, use_pallas=False))
    np.testing.assert_allclose(got, want, atol=1e-5)


@needs_artifacts
def test_exported_hlo_files_have_constants():
    for f in ARTIFACTS.glob("*_fwd_*.hlo.txt"):
        head = f.read_text()[:200]
        assert head.startswith("HloModule"), f.name
        assert "constant({...})" not in f.read_text(), f.name
