#!/usr/bin/env bash
# Tier-1 verify in one command: release build, full test suite, and a
# quick perf_hotpath smoke (the cached-vs-uncached sweep runs in its
# STRIDE_BENCH_QUICK=1 trim). Usage: scripts/ci.sh [--no-bench]
set -euo pipefail

cd "$(dirname "$0")/../rust"

if ! command -v cargo >/dev/null 2>&1; then
    echo "error: cargo not found on PATH — install the Rust toolchain" >&2
    echo "       (rustup.rs), then re-run scripts/ci.sh" >&2
    exit 1
fi

echo "== cargo build --release =="
cargo build --release

echo "== cargo test -q =="
cargo test -q

if [[ "${1:-}" != "--no-bench" ]]; then
    echo "== perf_hotpath smoke (STRIDE_BENCH_QUICK=1) =="
    STRIDE_BENCH_QUICK=1 cargo bench --bench perf_hotpath

    # The kernel-layer bench must leave a sane machine-readable record:
    # non-empty JSON with no NaN/inf timings (the perf trajectory file).
    json=results/BENCH_perf_hotpath.json
    if [[ ! -s "$json" ]]; then
        echo "error: $json missing or empty after perf_hotpath" >&2
        exit 1
    fi
    if grep -qiE 'nan|inf' "$json"; then
        echo "error: non-finite timing in $json:" >&2
        grep -iE 'nan|inf' "$json" >&2
        exit 1
    fi
    echo "kernel bench record OK: $json"
fi

echo "CI OK"
