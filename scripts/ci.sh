#!/usr/bin/env bash
# Tier-1 verify in one command: release build, full test suite, the
# rustdoc gate (crate docs must build with zero warnings), and quick
# bench smokes (perf_hotpath's cached-vs-uncached sweep and the adaptive
# controller bench, both in their STRIDE_BENCH_QUICK=1 trims).
# Usage: scripts/ci.sh [--no-bench]
set -euo pipefail

cd "$(dirname "$0")/../rust"

if ! command -v cargo >/dev/null 2>&1; then
    echo "error: cargo not found on PATH — install the Rust toolchain" >&2
    echo "       (rustup.rs), then re-run scripts/ci.sh" >&2
    exit 1
fi

echo "== cargo build --release =="
cargo build --release

echo "== cargo test -q =="
cargo test -q

# The chaos suite is part of the suite above; rerunning it alone makes a
# fault-tolerance regression name itself in the CI log instead of hiding
# in the aggregate count.
echo "== fault-injection chaos suite =="
cargo test -q --test fault_injection

# Same naming treatment for the observability surfaces: the flight-
# recorder end-to-end suite (request ids, per-round spans, Chrome
# export, wrap accounting, disabled-is-bit-identical), the zero-
# allocation discipline (which now pins TraceSink::record at zero), and
# the /metrics grammar + scrape-under-fire tests.
echo "== flight-recorder trace suite =="
cargo test -q --test trace_e2e
cargo test -q --test alloc_discipline
cargo test -q --test monitoring metrics_render_format_is_pinned \
    concurrent_metrics_scrape_stays_well_formed

# The trace suite persists a /debug/trace scrape taken under concurrent
# load; it must parse as JSON end-to-end (Chrome/Perfetto would reject
# anything torn). python3 when available, a shape grep otherwise.
if [[ -s results/trace_smoke.json ]]; then
    if command -v python3 >/dev/null 2>&1; then
        python3 -m json.tool results/trace_smoke.json >/dev/null \
            || { echo "error: results/trace_smoke.json is not valid JSON" >&2; exit 1; }
    elif ! grep -q '"ph"' results/trace_smoke.json; then
        echo "error: results/trace_smoke.json lacks trace-event shape" >&2
        exit 1
    fi
    echo "trace export OK: results/trace_smoke.json"
else
    echo "error: trace suite did not write results/trace_smoke.json" >&2
    exit 1
fi

# Rustdoc gate: the crate carries #![warn(missing_docs)]; -D warnings
# turns any missing public-API doc (or broken intra-doc link) into a hard
# failure. --lib avoids the doc-output name collision with the bin target.
echo "== cargo doc --no-deps (deny warnings) =="
RUSTDOCFLAGS="-D warnings" cargo doc --no-deps --lib

# Shared check for the machine-readable bench records (schema in
# benches/README.md): the file must exist, be non-empty, and contain no
# non-finite values.
check_bench_json() {
    local json="$1"
    if [[ ! -s "$json" ]]; then
        echo "error: $json missing or empty" >&2
        exit 1
    fi
    if grep -qiE 'nan|inf' "$json"; then
        echo "error: non-finite value in $json:" >&2
        grep -iE 'nan|inf' "$json" >&2
        exit 1
    fi
    echo "bench record OK: $json"
}

if [[ "${1:-}" != "--no-bench" ]]; then
    echo "== perf_hotpath smoke (STRIDE_BENCH_QUICK=1) =="
    # Kernel-tier criteria: the SIMD, tiled, and stacked-verify fast
    # paths must each be bitwise identical to the scalar / flat /
    # sequential forms they replace (asserted in-bench, recorded as
    # criteria_met), every timing must be finite, and the flight
    # recorder's trace_overhead section must show an observed decode
    # that is bit-identical and within its 5% budget.
    STRIDE_BENCH_QUICK=1 cargo bench --bench perf_hotpath
    check_bench_json results/BENCH_perf_hotpath.json
    if ! grep -q '"criteria_met":true' results/BENCH_perf_hotpath.json; then
        echo "error: perf_hotpath kernel-tier criteria not met" >&2
        exit 1
    fi

    echo "== adaptive_gamma smoke (STRIDE_BENCH_QUICK=1) =="
    # The bench exits non-zero itself if the controller misses its
    # acceptance criteria; the JSON check is belt-and-braces.
    STRIDE_BENCH_QUICK=1 cargo bench --bench adaptive_gamma
    check_bench_json results/BENCH_adaptive_gamma.json
    if ! grep -q '"criteria_met":true' results/BENCH_adaptive_gamma.json; then
        echo "error: adaptive_gamma criteria not met" >&2
        exit 1
    fi

    echo "== draft_sources smoke (STRIDE_BENCH_QUICK=1) =="
    # Pluggable-draft criteria: the online-adapted draft must out-accept
    # the frozen model draft after regime drift, and the draft-free
    # extrapolation source must measure the lowest cost ratio c.
    STRIDE_BENCH_QUICK=1 cargo bench --bench draft_sources
    check_bench_json results/BENCH_draft_sources.json
    if ! grep -q '"criteria_met":true' results/BENCH_draft_sources.json; then
        echo "error: draft_sources criteria not met" >&2
        exit 1
    fi

    echo "== serving_load smoke (STRIDE_BENCH_QUICK=1) =="
    # Serving-scheduler criteria: scheduled responses bit-identical to
    # the unscheduled engine at every replica count, throughput monotone
    # in replicas, and high-priority deadline attainment under 2x
    # overload >= the single-replica FIFO baseline.
    STRIDE_BENCH_QUICK=1 cargo bench --bench serving_load
    check_bench_json results/BENCH_serving_load.json
    if ! grep -q '"criteria_met":true' results/BENCH_serving_load.json; then
        echo "error: serving_load criteria not met" >&2
        exit 1
    fi

    echo "== tree_speculation smoke (STRIDE_BENCH_QUICK=1) =="
    # Tree-speculation criteria: the k=4 mean accepted run must be
    # strictly longer than k=1 overall and in every acceptance regime,
    # measured full-gamma runs must track the independent-branch
    # law E[L_k] - 1 = sum(1 - (1 - alpha^i)^k), and the stacked
    # (one-batched-forward) verify must emit bits identical to the
    # retained sequential reference on the native workload.
    STRIDE_BENCH_QUICK=1 cargo bench --bench tree_speculation
    check_bench_json results/BENCH_tree_speculation.json
    if ! grep -q '"criteria_met":true' results/BENCH_tree_speculation.json; then
        echo "error: tree_speculation criteria not met" >&2
        exit 1
    fi

    echo "== chaos_soak smoke (STRIDE_BENCH_QUICK=1) =="
    # Fault-tolerance criteria: every request under seeded chaos reaches
    # a typed terminal outcome, no served response carries a non-finite
    # bit, replica restarts equal injected panics, and the post-budget
    # recovery tail is error-free.
    STRIDE_BENCH_QUICK=1 cargo bench --bench chaos_soak
    check_bench_json results/BENCH_chaos_soak.json
    if ! grep -q '"criteria_met":true' results/BENCH_chaos_soak.json; then
        echo "error: chaos_soak criteria not met" >&2
        exit 1
    fi

    echo "== model_swap smoke (STRIDE_BENCH_QUICK=1) =="
    # Live-swap criteria: zero requests dropped or errored across a
    # mid-soak hot swap, swap-window p99 <= 2x steady-state, and the
    # serving digest lands on the new manifest's content address with
    # every replica rebound.
    STRIDE_BENCH_QUICK=1 cargo bench --bench model_swap
    check_bench_json results/BENCH_model_swap.json
    if ! grep -q '"criteria_met":true' results/BENCH_model_swap.json; then
        echo "error: model_swap criteria not met" >&2
        exit 1
    fi
fi

echo "CI OK"
